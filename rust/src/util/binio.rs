//! Tiny little-endian binary writer/reader for the on-disk artifact
//! format (the vendored crate set has no serde/bincode — see Cargo.toml).
//!
//! Framing conventions, shared by every artifact section:
//! * integers are little-endian (`u8`/`u32`/`u64`);
//! * byte strings and UTF-8 strings are `u32` length + raw bytes;
//! * readers never trust a length: every read is bounded by the remaining
//!   buffer and fails with a `truncated` error instead of panicking, so a
//!   cut-off file degrades to a clean load failure.

use anyhow::bail;

/// FNV-1a 64 over a byte slice — the artifact payload checksum (same
/// constants as the constraint fingerprints).
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Append-only buffer writer.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter { buf: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_inner(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Raw bytes, no length prefix (fixed-layout sections).
    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Length-prefixed byte string.
    pub fn bytes(&mut self, bytes: &[u8]) {
        self.u32(bytes.len() as u32);
        self.buf.extend_from_slice(bytes);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
    }
}

/// Bounds-checked buffer reader.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// The rest of the buffer, without consuming it.
    pub fn rest(&self) -> &'a [u8] {
        &self.buf[self.pos..]
    }

    fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("truncated: need {n} bytes, {} remain", self.remaining());
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Exactly `n` raw bytes (fixed-layout fields, e.g. magic numbers).
    pub fn raw(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        self.take(n)
    }

    pub fn u8(&mut self) -> crate::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> crate::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> crate::Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// A `u32`-length-prefixed byte string.
    pub fn bytes(&mut self) -> crate::Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    /// A `u32`-length-prefixed UTF-8 string.
    pub fn str(&mut self) -> crate::Result<String> {
        let b = self.bytes()?;
        Ok(std::str::from_utf8(b)
            .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string field"))?
            .to_string())
    }

    /// Fail unless the whole buffer was consumed (trailing garbage means
    /// the encoder and decoder disagree about the layout).
    pub fn expect_end(&self) -> crate::Result<()> {
        if !self.is_empty() {
            bail!("{} trailing bytes after decode", self.remaining());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 1);
        w.bytes(b"abc");
        w.str("héllo");
        w.raw(&[1, 2, 3]);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.bytes().unwrap(), b"abc");
        assert_eq!(r.str().unwrap(), "héllo");
        assert_eq!(r.remaining(), 3);
        assert_eq!(r.u8().unwrap(), 1);
        assert_eq!(r.u8().unwrap(), 2);
        assert_eq!(r.u8().unwrap(), 3);
        assert!(r.expect_end().is_ok());
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = ByteWriter::new();
        w.u64(42);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf[..5]);
        assert!(r.u64().is_err());
        // A length prefix larger than the buffer is rejected too.
        let mut w = ByteWriter::new();
        w.u32(1_000_000);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        assert!(r.bytes().is_err());
    }

    #[test]
    fn expect_end_catches_trailing_bytes() {
        let mut w = ByteWriter::new();
        w.u8(1);
        w.u8(2);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        r.u8().unwrap();
        assert!(r.expect_end().is_err());
        r.u8().unwrap();
        assert!(r.expect_end().is_ok());
    }

    #[test]
    fn fnv_is_stable_and_content_sensitive() {
        assert_eq!(fnv1a_64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_64(b"domino"), fnv1a_64(b"domino"));
        assert_ne!(fnv1a_64(b"domino"), fnv1a_64(b"dominp"));
    }
}
