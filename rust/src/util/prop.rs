//! Tiny property-test driver (the vendored crate set has no proptest).
//!
//! Runs a closure over many seeded cases; on failure reports the seed so
//! the case can be replayed deterministically.

use super::rng::Rng;

/// Run `f` for `cases` seeded RNGs. Panics with the failing seed.
pub fn check(name: &str, cases: u64, mut f: impl FnMut(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xD0111_u64.wrapping_mul(seed + 1));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property `{name}` failed at seed {seed}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("add-commutes", 50, |rng| {
            let a = rng.range(-1000, 1000);
            let b = rng.range(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed at seed 0")]
    fn reports_failing_seed() {
        check("always-fails", 5, |_| panic!("boom"));
    }
}
