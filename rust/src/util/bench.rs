//! Bench-table infrastructure (the vendored crate set has no criterion).
//!
//! Each `rust/benches/*.rs` is a `harness = false` main that measures its
//! workloads and prints a markdown table mirroring the corresponding table
//! or figure of the paper. [`Table`] handles alignment; [`time_it`] does
//! warmup + repeated timing.

use std::time::{Duration, Instant};

/// Markdown-ish aligned table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", line(&sep));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Measurement result.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters: u32,
}

impl Timing {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }
}

/// Merge one bench's metrics into the JSON report named by
/// `$DOMINO_BENCH_JSON` (no-op when unset). Each bench writes its own
/// top-level `section` object, so several benches can build one
/// `BENCH_ci.json` sequentially — the machine-readable output CI uploads
/// and diffs against the checked-in baseline.
pub fn emit_json(section: &str, fields: &[(&str, f64)]) {
    use crate::util::Json;
    use std::collections::BTreeMap;
    let Some(path) = std::env::var_os("DOMINO_BENCH_JSON") else { return };
    let mut root = match std::fs::read_to_string(&path).ok().and_then(|s| Json::parse(&s).ok()) {
        Some(Json::Obj(m)) => m,
        _ => BTreeMap::new(),
    };
    let mut obj = BTreeMap::new();
    for &(name, value) in fields {
        if value.is_finite() {
            obj.insert(name.to_string(), Json::Num(value));
        }
    }
    root.insert(section.to_string(), Json::Obj(obj));
    if let Err(e) = std::fs::write(&path, Json::Obj(root).to_string()) {
        eprintln!("warn: could not write bench json: {e}");
    }
}

/// Warm up then time `f` for `iters` iterations.
pub fn time_it(warmup: u32, iters: u32, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut min = Duration::MAX;
    let mut max = Duration::ZERO;
    let mut total = Duration::ZERO;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed();
        min = min.min(dt);
        max = max.max(dt);
        total += dt;
    }
    Timing { mean: total / iters.max(1), min, max, iters }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_runs() {
        let t = time_it(1, 5, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(t.iters, 5);
        assert!(t.min <= t.mean && t.mean <= t.max.max(t.mean));
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["grammar", "throughput"]);
        t.row(&["json".into(), "1.77x".into()]);
        t.print();
    }
}
