//! Deterministic PRNG (xoshiro256**) — benches, property tests, sampling.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // SplitMix64 expansion of the seed.
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_distribution_sane() {
        let mut r = Rng::new(2);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 2];
        for _ in 0..1000 {
            counts[r.weighted(&[1.0, 9.0])] += 1;
        }
        assert!(counts[1] > 800, "{counts:?}");
    }
}
