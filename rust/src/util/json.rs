//! Minimal JSON value type + parser + serializer.
//!
//! Serves three roles: (a) the serialization format for
//! `artifacts/tokenizer.json` / `model_config.json`, (b) the *semantic
//! oracle* the eval harness uses to check well-formedness and extract
//! answers from generated output (independent of the JSON *grammar* used
//! for constraining), (c) config/CLI plumbing.

use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> crate::Result<Json> {
        let bytes = src.as_bytes();
        let mut p = P { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            bail!("json: trailing input at byte {}", p.i);
        }
        Ok(v)
    }

    /// Parse a JSON value from the *start* of `src`, ignoring trailing text
    /// (what an unconstrained LLM emits after the closing brace). Returns
    /// the value and the number of bytes consumed.
    pub fn parse_prefix(src: &str) -> crate::Result<(Json, usize)> {
        let mut p = P { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        Ok((v, p.i))
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct P<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> P<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> crate::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("json: expected `{}` at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> crate::Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("json: unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> crate::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("json: bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => bail!("json: expected , or }} got {:?} at byte {}", other.map(|c| c as char), self.i),
            }
        }
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                other => bail!("json: expected , or ] got {:?} at byte {}", other.map(|c| c as char), self.i),
            }
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("json: unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().context("json: dangling escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("json: truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => bail!("json: bad escape \\{}", other as char),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 character (bytes copied verbatim).
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xc0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap_or("\u{fffd}"));
                }
            }
        }
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().map_or(false, |c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse().with_context(|| format!("json: bad number `{text}`"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"answer": 42, "thoughts": [{"step": "a\nb", "result": -1.5}], "ok": true, "x": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("answer").unwrap().as_f64(), Some(42.0));
        let reparsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, reparsed);
    }

    #[test]
    fn prefix_parse_ignores_trailing() {
        let (v, n) = Json::parse_prefix("{\"a\": 1} and then the model rambles").unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(&"{\"a\": 1} and then the model rambles"[..n], "{\"a\": 1}");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "{\"a\" 1}", "[1,]", "tru", "\"unterminated", "{\"a\":1}x"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ✓""#).unwrap();
        assert_eq!(v.as_str(), Some("café ✓"));
    }
}
