//! Offline-build utility substrate: JSON, PRNG, property-test driver,
//! bench table printer. (The image's vendored crate set has no serde_json /
//! rand / proptest / criterion — see Cargo.toml.)

pub mod bench;
pub mod binio;
pub mod json;
pub mod prop;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
