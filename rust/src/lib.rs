//! # DOMINO — fast, non-invasive constrained generation
//!
//! Reproduction of *"Guiding LLMs The Right Way: Fast, Non-Invasive
//! Constrained Generation"* (Beurer-Kellner, Fischer, Vechev — ICML 2024).
//!
//! The crate is organised in three tiers:
//!
//! 1. **Substrates** — [`regex`] (Thompson NFAs), [`grammar`] (EBNF → CFG
//!    and the [`grammar::jsonschema`] JSON Schema → CFG front-end),
//!    [`tokenizer`] (byte-level BPE). Everything DOMINO depends on is built
//!    from scratch here.
//! 2. **The paper's contribution** — [`scanner`] (character-level union NFA,
//!    §3.2), [`parser`] (incremental Earley, §3.4), [`domino`] (subterminal
//!    trees per Alg. 2, lookahead-k masking, opportunistic masking and
//!    count-based speculative decoding, §3.5–3.6), plus the [`baselines`]
//!    the paper evaluates against.
//! 3. **Serving runtime** — [`constraint`] (first-class constraint specs,
//!    the shared [`EngineRegistry`](constraint::EngineRegistry) that
//!    amortizes grammar precomputation across requests, and the
//!    state-keyed mask cache), [`runtime`] (PJRT client over AOT-compiled
//!    JAX HLO; python never runs on the request path — gated behind the
//!    `xla` cargo feature, with the mock backend as the default),
//!    [`server`] (sharded scheduler: N engine threads sharing the
//!    registry, grammar-affinity routing, bounded queues with overload
//!    shedding, deadlines/cancellation, streaming), [`eval`] (workloads,
//!    metrics, the paper's tables).
//!
//! See `DESIGN.md` for the per-experiment index and the constraint
//! pipeline's architecture notes.

pub mod baselines;
pub mod constraint;
pub mod domino;
pub mod eval;
pub mod grammar;
pub mod parser;
pub mod regex;
pub mod runtime;
pub mod scanner;
pub mod server;
pub mod tokenizer;
pub mod util;

/// Token id within the LLM vocabulary.
pub type TokenId = u32;

/// Crate-wide error type.
pub type Error = anyhow::Error;
pub type Result<T> = anyhow::Result<T>;
