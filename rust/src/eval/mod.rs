//! Evaluation harness — everything needed to regenerate the paper's
//! tables and figures (see DESIGN.md per-experiment index).
//!
//! * [`workload`] — synthetic GSM8K-style math problems and CoNLL-style
//!   NER sentences with known answers, plus the App. C format prompts.
//!   Formats mirror `python/compile/data.py` exactly; test problems are
//!   freshly sampled (held out from the training corpus by seed).
//! * [`score`] — well-formedness + answer extraction + task accuracy.
//! * [`retokenize`] — Algorithm 3 (App. B): model-preferred retokenization
//!   used by the Fig. 2 misalignment analysis.
//! * [`harness`] — the method×task runners shared by `rust/benches/*`:
//!   each returns the row metrics the paper reports (accuracy,
//!   well-formed, perplexity, relative throughput).

pub mod harness;
pub mod retokenize;
pub mod score;
pub mod workload;

pub use harness::{workload_spec, Method, Setup};
