//! Algorithm 3 (App. B): model-based retokenization.
//!
//! Greedily re-encode a target text with the tokens the model itself
//! would pick — the "naturalized" tokenization used to demonstrate
//! template-induced misalignment (Fig. 2): forced template tokens often
//! differ from the model-preferred tokens for the *same* text, and the
//! model assigns them much lower probability.

use crate::runtime::sampler::log_prob;
use crate::runtime::LmSession;
use crate::tokenizer::Vocab;
use crate::TokenId;

/// Result of a retokenization pass.
#[derive(Clone, Debug)]
pub struct Retokenized {
    pub tokens: Vec<TokenId>,
    /// Sum of `log P(token)` along the chosen tokenization.
    pub logprob_sum: f64,
}

impl Retokenized {
    pub fn perplexity(&self) -> f64 {
        if self.tokens.is_empty() {
            return f64::NAN;
        }
        (-self.logprob_sum / self.tokens.len() as f64).exp()
    }
}

/// Algorithm 3: after `prompt` (already appended to `lm`), re-encode
/// `target` choosing at each step the highest-logit token that is a
/// prefix of the remaining text.
pub fn retokenize(
    lm: &mut dyn LmSession,
    vocab: &Vocab,
    prompt: &[TokenId],
    target: &[u8],
) -> crate::Result<Retokenized> {
    let mut logits = lm.append(prompt)?;
    let mut out = Retokenized { tokens: Vec::new(), logprob_sum: 0.0 };
    let mut rest: &[u8] = target;
    while !rest.is_empty() {
        // argmax over tokens that are a prefix of `rest`.
        let mut best: Option<(TokenId, f32)> = None;
        for id in 0..vocab.len() as TokenId {
            let b = vocab.token_bytes(id);
            if b.is_empty() || b.len() > rest.len() || &rest[..b.len()] != b {
                continue;
            }
            let score = logits[id as usize];
            if best.map_or(true, |(_, s)| score > s) {
                best = Some((id, score));
            }
        }
        let (tok, _) = best.expect("byte tokens make some prefix always available");
        out.logprob_sum += log_prob(&logits, tok);
        rest = &rest[vocab.token_bytes(tok).len()..];
        logits = lm.append(&[tok])?;
        out.tokens.push(tok);
    }
    Ok(out)
}

/// Score an *imposed* tokenization (e.g. the template-forced one): the
/// model's log-probability of exactly that token sequence after `prompt`.
pub fn score_tokenization(
    lm: &mut dyn LmSession,
    prompt: &[TokenId],
    tokens: &[TokenId],
) -> crate::Result<Retokenized> {
    let mut logits = lm.append(prompt)?;
    let mut sum = 0.0;
    for &t in tokens {
        sum += log_prob(&logits, t);
        logits = lm.append(&[t])?;
    }
    Ok(Retokenized { tokens: tokens.to_vec(), logprob_sum: sum })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::{json_mock, MockLm};

    #[test]
    fn retokenization_covers_target() {
        let (vocab, model) = json_mock(512);
        let mut lm = MockLm::new(model);
        let target = b"{\"name\": \"John Doe\"}";
        let r = retokenize(&mut lm, &vocab, &[], target).unwrap();
        assert_eq!(vocab.decode(&r.tokens), target);
        assert!(r.logprob_sum.is_finite());
    }

    #[test]
    fn model_preferred_beats_byte_by_byte() {
        // The naturalized tokenization must score at least as well as the
        // worst-case byte-level tokenization of the same text.
        let (vocab, model) = json_mock(512);
        let target = b"{\"name\": \"John Doe\"}";

        let mut lm1 = MockLm::new(model.clone());
        let natural = retokenize(&mut lm1, &vocab, &[], target).unwrap();

        let bytes: Vec<crate::TokenId> = target
            .iter()
            .map(|&b| (b as usize + crate::tokenizer::NUM_SPECIAL) as crate::TokenId)
            .collect();
        let mut lm2 = MockLm::new(model);
        let forced = score_tokenization(&mut lm2, &[], &bytes).unwrap();

        // Compare per-byte normalized log-prob (different token counts).
        assert!(natural.logprob_sum >= forced.logprob_sum - 1e-9);
    }
}
