//! Method × task runners shared by the benches (one per paper table).

use super::score;
use super::workload;
use crate::baselines::template::{conll_program, gsm8k_program, TemplateRuntime};
use crate::baselines::OnlineChecker;
use crate::constraint::{ConstraintSpec, EngineRegistry};
use crate::domino::decoder::{Engine as GrammarEngine, Lookahead};
use crate::domino::generate::Prompt;
use crate::domino::{
    generate, generate_drafted, generate_speculative, DominoDecoder, GenConfig, MaskMode,
    SpeculativeModel, Unconstrained,
};
use crate::runtime::mock::{json_mock, MockLm, MockModel};
use crate::runtime::pjrt::{artifacts_dir, load_vocab, PjrtLm, PjrtModel};
use crate::runtime::sampler::Sampling;
use crate::runtime::LmSession;
use crate::tokenizer::Vocab;
use crate::util::Rng;
use std::sync::Arc;
use std::time::Instant;

/// Model backend: the AOT bundle if present, the mock otherwise (so
/// benches/tests run on a fresh checkout; the bench banner says which).
pub enum Backend {
    Pjrt(Arc<PjrtModel>),
    Mock(Arc<MockModel>),
}

pub struct Setup {
    pub vocab: Arc<Vocab>,
    pub backend: Backend,
    pub backend_name: &'static str,
    /// Shared compiled-engine cache: bench tables request the same
    /// grammar row after row, so precompute is paid once per grammar.
    pub registry: Arc<EngineRegistry>,
}

/// Engines kept hot by the harness registry (≥ the builtin grammar set).
const REGISTRY_CAPACITY: usize = 16;

impl Setup {
    /// Load artifacts if available, else fall back to the mock LM.
    pub fn load() -> Setup {
        let registry = EngineRegistry::new(REGISTRY_CAPACITY);
        let dir = artifacts_dir();
        if dir.join("model_config.json").exists() {
            match (PjrtModel::load(&dir), load_vocab(&dir)) {
                (Ok(model), Ok(vocab)) => {
                    return Setup {
                        vocab,
                        backend: Backend::Pjrt(model),
                        backend_name: "pjrt-aot",
                        registry,
                    };
                }
                (a, b) => {
                    eprintln!(
                        "warn: artifacts load failed ({:?} / {:?}); using mock",
                        a.err().map(|e| e.to_string()),
                        b.err().map(|e| e.to_string())
                    );
                }
            }
        }
        let (vocab, model) = json_mock(512);
        Setup { vocab, backend: Backend::Mock(model), backend_name: "mock-trigram", registry }
    }

    pub fn session(&self) -> crate::Result<Box<dyn LmSession>> {
        Ok(match &self.backend {
            Backend::Pjrt(m) => Box::new(PjrtLm::new(m.clone())?),
            Backend::Mock(m) => Box::new(MockLm::new(m.clone())),
        })
    }

    /// Compiled engine for a named eval workload (builtin grammar names
    /// plus the schema-driven `function_call` workload), via the shared
    /// registry. The harness deliberately shares one engine build
    /// (k = ∞ key) across its lookahead rows — the compiled tables are
    /// identical and the tables compare per-`k` *decode* behavior, not
    /// builds.
    pub fn engine(&self, grammar: &str) -> crate::Result<Arc<GrammarEngine>> {
        self.engine_spec(&workload_spec(grammar))
    }

    /// Compiled engine for an arbitrary constraint spec, via the shared
    /// registry (what `benches/schema_compile.rs` and schema eval rows
    /// use).
    pub fn engine_spec(&self, spec: &ConstraintSpec) -> crate::Result<Arc<GrammarEngine>> {
        let (engine, _masks) = self.registry.get_or_compile(spec, &self.vocab, None)?;
        Ok(engine)
    }
}

/// The [`ConstraintSpec`] behind a named eval workload: the builtin
/// grammars by name, plus `function_call` — the JSON-Schema-compiled
/// tool-call workload ([`workload::FUNCTION_CALL_SCHEMA`]).
pub fn workload_spec(name: &str) -> ConstraintSpec {
    match name {
        "function_call" => ConstraintSpec::json_schema(workload::FUNCTION_CALL_SCHEMA),
        other => ConstraintSpec::builtin(other),
    }
}

/// The decoding methods of Tables 2–4.
#[derive(Clone, Debug)]
pub enum Method {
    Unconstrained,
    /// GUIDANCE-style template program; `ws` = App. A whitespace-flexible.
    Guidance { ws: bool },
    /// Online parser-guided masking, no precomputation.
    /// `opportunistic=true` = llama.cpp (check the proposal first, Table 3
    /// footnote); `false` = GCD/PICARD-style full-vocabulary mask every
    /// step.
    Online { opportunistic: bool },
    /// DOMINO at lookahead `k`, optionally with §3.6 speculation;
    /// `opportunistic=false` = Algorithm 1's full mask every step.
    Domino { k: Lookahead, spec: Option<usize>, opportunistic: bool },
    /// DOMINO with the grammar-pruned draft lane: up to `draft` tokens
    /// proposed per step from the learned prior. `prune=true` filters
    /// each draft token through the grammar as the proposal is built
    /// (prune-before-verify); `false` is the ablation that verifies the
    /// unpruned proposal and rejects illegal tokens afterwards.
    Drafted { k: Lookahead, draft: usize, prune: bool },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Unconstrained => "Unconstrained".into(),
            Method::Guidance { ws: false } => "Guidance".into(),
            Method::Guidance { ws: true } => "Guidance WS".into(),
            Method::Online { opportunistic: true } => "llama.cpp (online, opp.)".into(),
            Method::Online { opportunistic: false } => "GCD (online, full mask)".into(),
            Method::Domino { k, spec, opportunistic } => {
                let k = match k {
                    Lookahead::K(k) => format!("k={k}"),
                    Lookahead::Infinite => "k=inf".into(),
                };
                match (spec, opportunistic) {
                    (Some(s), _) => format!("Domino ({k}, spec s={s})"),
                    (None, true) => format!("Domino ({k}, opp.)"),
                    (None, false) => format!("Domino ({k})"),
                }
            }
            Method::Drafted { k, draft, prune } => {
                let k = match k {
                    Lookahead::K(k) => format!("k={k}"),
                    Lookahead::Infinite => "k=inf".into(),
                };
                let order = if *prune { "pre-prune" } else { "post-prune" };
                format!("Domino drafted ({k}, K={draft}, {order})")
            }
        }
    }

    /// The mask cost mode this method runs under.
    pub fn mask_mode(&self) -> MaskMode {
        match self {
            Method::Online { opportunistic: false } => MaskMode::FullMask,
            Method::Domino { opportunistic: false, spec: None, .. } => MaskMode::FullMask,
            _ => MaskMode::Opportunistic,
        }
    }
}

/// One table row's measurements.
#[derive(Clone, Debug, Default)]
pub struct RowMetrics {
    pub n: usize,
    pub accuracy: f64,
    pub well_formed: f64,
    pub perplexity: f64,
    pub tokens: usize,
    pub toks_per_s: f64,
    pub interventions: usize,
    pub model_calls: usize,
    pub elapsed_s: f64,
    /// Tokens proposed by speculation/drafting across the row.
    pub spec_proposed: usize,
    /// Proposed tokens accepted by verification across the row.
    pub spec_accepted: usize,
}

struct TaskOutcome {
    text: String,
    tokens: usize,
    logprob_sum: f64,
    interventions: usize,
    model_calls: usize,
    spec_proposed: usize,
    spec_accepted: usize,
}

/// Run one generation with `method` for a task-grammar; returns the text
/// and stats.
#[allow(clippy::too_many_arguments)]
fn run_one(
    setup: &Setup,
    method: &Method,
    grammar: &str,
    engine: Option<&Arc<GrammarEngine>>,
    spec_model: &mut SpeculativeModel,
    prompt: &str,
    cfg: &GenConfig,
    rng: &mut Rng,
) -> crate::Result<TaskOutcome> {
    let mut lm = setup.session()?;
    // Prompt-boundary token healing for every token-level method (§3.5);
    // the template engine heals its own literal boundaries.
    let healed = Prompt::healed(&setup.vocab, prompt);
    match method {
        Method::Unconstrained => {
            let mut checker = Unconstrained::new(setup.vocab.len());
            let r = generate(lm.as_mut(), &mut checker, &setup.vocab, &healed, cfg, rng)?;
            Ok(TaskOutcome {
                text: r.text(),
                tokens: r.tokens.len(),
                logprob_sum: r.logprob_sum,
                interventions: r.interventions,
                model_calls: r.model_calls,
                spec_proposed: 0,
                spec_accepted: 0,
            })
        }
        Method::Guidance { ws } => {
            let program = match grammar {
                "gsm8k" => gsm8k_program(1),
                "conll" => conll_program(2),
                "template" => crate::baselines::template::rpg_program(),
                _ => crate::baselines::template::person_program(),
            };
            let program = if *ws { program.ws_flexible() } else { program };
            let rt = TemplateRuntime::compile(program, setup.vocab.clone(), true)?;
            let r = rt.run_with_prompt(lm.as_mut(), prompt, cfg.sampling, rng)?;
            Ok(TaskOutcome {
                text: r.text.clone(),
                tokens: r.tokens.len(),
                logprob_sum: r.logprob_sum,
                interventions: 0,
                model_calls: r.model_calls,
                spec_proposed: 0,
                spec_accepted: 0,
            })
        }
        Method::Online { .. } => {
            let engine = engine.expect("grammar engine required");
            let mut checker = OnlineChecker::new(engine.clone());
            let r = generate(lm.as_mut(), &mut checker, &setup.vocab, &healed, cfg, rng)?;
            Ok(TaskOutcome {
                text: r.text(),
                tokens: r.tokens.len(),
                logprob_sum: r.logprob_sum,
                interventions: r.interventions,
                model_calls: r.model_calls,
                spec_proposed: 0,
                spec_accepted: 0,
            })
        }
        Method::Domino { k, spec, .. } => {
            let engine = engine.expect("grammar engine required");
            let mut decoder = DominoDecoder::new(engine.clone(), *k);
            let r = match spec {
                Some(s) => generate_speculative(
                    lm.as_mut(),
                    &mut decoder,
                    spec_model,
                    &setup.vocab,
                    &healed,
                    *s,
                    cfg,
                    rng,
                )?,
                None => generate(lm.as_mut(), &mut decoder, &setup.vocab, &healed, cfg, rng)?,
            };
            Ok(TaskOutcome {
                text: r.text(),
                tokens: r.tokens.len(),
                logprob_sum: r.logprob_sum,
                interventions: r.interventions,
                model_calls: r.model_calls,
                spec_proposed: r.spec_proposed,
                spec_accepted: r.spec_accepted,
            })
        }
        Method::Drafted { k, draft, prune } => {
            let engine = engine.expect("grammar engine required");
            let mut decoder = DominoDecoder::new(engine.clone(), *k);
            let r = generate_drafted(
                lm.as_mut(),
                &mut decoder,
                spec_model,
                &setup.vocab,
                &healed,
                *draft,
                *prune,
                cfg,
                rng,
            )?;
            Ok(TaskOutcome {
                text: r.text(),
                tokens: r.tokens.len(),
                logprob_sum: r.logprob_sum,
                interventions: r.interventions,
                model_calls: r.model_calls,
                spec_proposed: r.spec_proposed,
                spec_accepted: r.spec_accepted,
            })
        }
    }
}

/// Shared row runner: samples `n` tasks for `task_kind` ("gsm8k"/"conll"),
/// runs `method`, scores accuracy/well-formedness/perplexity/throughput.
pub fn eval_task(
    setup: &Setup,
    method: &Method,
    task_kind: &str,
    n: usize,
    max_tokens: usize,
    seed: u64,
) -> crate::Result<RowMetrics> {
    let engine = match method {
        Method::Unconstrained | Method::Guidance { .. } => None,
        _ => Some(setup.engine(task_kind)?),
    };
    let mut spec_model = SpeculativeModel::new(0.75);
    let cfg = GenConfig { max_tokens, sampling: Sampling::Greedy, mode: method.mask_mode() };
    let mut rng = Rng::new(seed);

    // Speculation warmup (paper: priors over 10 samples, then frozen).
    if matches!(method, Method::Domino { spec: Some(_), .. } | Method::Drafted { .. }) {
        for _ in 0..10 {
            let prompt = task_prompt(task_kind, &mut rng);
            let _ = run_one(setup, method, task_kind, engine.as_ref(), &mut spec_model, &prompt, &cfg, &mut rng);
        }
        spec_model.frozen = true;
    }

    let mut row = RowMetrics { n, ..Default::default() };
    let mut ppl_sum = 0.0;
    let mut ppl_n = 0usize;
    let t0 = Instant::now();
    let mut task_rng = Rng::new(seed ^ 0xEEAA);
    for _ in 0..n {
        let (prompt, check): (String, Box<dyn Fn(&str) -> (bool, bool)>) = match task_kind {
            "gsm8k" => {
                let task = workload::math_task(&mut task_rng);
                let p = task.prompt();
                (p, Box::new(move |out: &str| {
                    (score::math_correct(&task, out), score::well_formed_json(out, false))
                }))
            }
            "conll" => {
                let task = workload::ner_task(&mut task_rng);
                let p = task.prompt();
                (p, Box::new(move |out: &str| {
                    let (_, exact) = score::ner_f1(&task, out);
                    (exact, score::well_formed_json(out, false))
                }))
            }
            other => panic!("unknown task kind {other}"),
        };
        let out = run_one(setup, method, task_kind, engine.as_ref(), &mut spec_model, &prompt, &cfg, &mut rng)?;
        let (correct, wf) = check(&out.text);
        row.accuracy += correct as usize as f64;
        row.well_formed += wf as usize as f64;
        row.tokens += out.tokens;
        row.interventions += out.interventions;
        row.model_calls += out.model_calls;
        row.spec_proposed += out.spec_proposed;
        row.spec_accepted += out.spec_accepted;
        if out.tokens > 0 {
            ppl_sum += (-out.logprob_sum / out.tokens as f64).exp();
            ppl_n += 1;
        }
    }
    row.elapsed_s = t0.elapsed().as_secs_f64();
    row.accuracy /= n as f64;
    row.well_formed /= n as f64;
    row.perplexity = if ppl_n > 0 { ppl_sum / ppl_n as f64 } else { f64::NAN };
    row.toks_per_s = row.tokens as f64 / row.elapsed_s.max(1e-9);
    Ok(row)
}

fn task_prompt(task_kind: &str, rng: &mut Rng) -> String {
    match task_kind {
        "gsm8k" => workload::math_task(rng).prompt(),
        "conll" => workload::ner_task(rng).prompt(),
        other => workload::format_prompt(other, rng),
    }
}

/// Table 3-style throughput run: free-format generation under `grammar`,
/// temperature 1.0, `n` repetitions.
pub fn eval_throughput(
    setup: &Setup,
    method: &Method,
    grammar: &str,
    n: usize,
    max_tokens: usize,
    seed: u64,
) -> crate::Result<RowMetrics> {
    let engine = match method {
        Method::Unconstrained | Method::Guidance { .. } => None,
        _ => Some(setup.engine(grammar)?),
    };
    let mut spec_model = SpeculativeModel::new(0.75);
    let cfg = GenConfig {
        max_tokens,
        sampling: Sampling::Temperature(1.0),
        mode: method.mask_mode(),
    };
    let mut rng = Rng::new(seed);
    // Warmup (forms speculation priors; also warms PJRT).
    for _ in 0..3 {
        let prompt = task_prompt(grammar, &mut rng);
        let _ = run_one(setup, method, grammar, engine.as_ref(), &mut spec_model, &prompt, &cfg, &mut rng);
    }
    spec_model.frozen = true;

    let mut row = RowMetrics { n, ..Default::default() };
    let mut wf = 0usize;
    let t0 = Instant::now();
    for _ in 0..n {
        let prompt = task_prompt(grammar, &mut rng);
        let out = run_one(setup, method, grammar, engine.as_ref(), &mut spec_model, &prompt, &cfg, &mut rng)?;
        row.tokens += out.tokens;
        row.interventions += out.interventions;
        row.model_calls += out.model_calls;
        row.spec_proposed += out.spec_proposed;
        row.spec_accepted += out.spec_accepted;
        let jsonish = grammar.contains("json") || grammar == "function_call";
        if score::well_formed_json(&out.text, false) || !jsonish {
            wf += 1;
        }
    }
    row.elapsed_s = t0.elapsed().as_secs_f64();
    row.well_formed = wf as f64 / n as f64;
    row.toks_per_s = row.tokens as f64 / row.elapsed_s.max(1e-9);
    Ok(row)
}

/// One tenant's view of a [`run_contention`] scenario.
#[derive(Clone, Debug, Default)]
pub struct TenantOutcome {
    pub submitted: usize,
    pub completed: usize,
    /// Requests shed at admission (queue full or quota).
    pub shed: u64,
    pub queue_wait_p50_ms: f64,
    pub queue_wait_p99_ms: f64,
}

/// Multi-tenant contention scenario shape. The hot tenant floods the
/// scheduler with a backlog submitted *before* the cold tenant's
/// requests — the worst case for FIFO drain, and exactly what the
/// weighted-fair queue is supposed to absorb.
#[derive(Clone, Debug)]
pub struct ContentionConfig {
    pub hot_n: usize,
    pub cold_n: usize,
    /// Deficit-round-robin weight for the cold tenant (hot stays at 1).
    pub cold_weight: u32,
    pub max_tokens: usize,
    pub slots: usize,
    pub queue_depth: usize,
}

impl Default for ContentionConfig {
    fn default() -> Self {
        ContentionConfig {
            hot_n: 0,
            cold_n: 16,
            cold_weight: 1,
            max_tokens: 32,
            slots: 4,
            queue_depth: 4096,
        }
    }
}

fn contention_request(
    tenant: &str,
    max_tokens: usize,
    seed: u64,
) -> crate::server::engine::GenRequest {
    crate::server::engine::GenRequest {
        prompt: String::new(),
        constraint: crate::constraint::Constraint::domino(ConstraintSpec::builtin("json")),
        max_tokens,
        temperature: Some(1.0),
        seed,
        tenant: Some(tenant.to_string()),
        ..Default::default()
    }
}

/// Run the multi-tenant contention scenario on the mock runtime (one
/// engine shard, so every request contends for the same slots) and
/// return `(hot, cold)` outcomes with per-tenant queue-wait percentiles
/// from the scheduler's own metrics. With `hot_n = 0` this doubles as
/// the cold tenant's solo baseline.
pub fn run_contention(cfg: &ContentionConfig) -> crate::Result<(TenantOutcome, TenantOutcome)> {
    use crate::runtime::mock::MockFactory;
    use crate::server::engine::EngineCtx;
    use crate::server::scheduler::{Scheduler, SchedulerConfig, TenantPolicy};

    let (vocab, model) = json_mock(512);
    let mut weights = std::collections::HashMap::new();
    weights.insert("cold".to_string(), cfg.cold_weight);
    let sched = Scheduler::start(
        move |_shard, registry| {
            Ok(EngineCtx::with_registry(
                Box::new(MockFactory { model: model.clone() }),
                vocab.clone(),
                registry,
            ))
        },
        SchedulerConfig {
            engines: 1,
            slots_per_engine: cfg.slots,
            queue_depth: cfg.queue_depth,
            tenants: TenantPolicy { weights, ..Default::default() },
            ..SchedulerConfig::default()
        },
    );
    // Warm the grammar compile (default tenant) so queue waits measure
    // scheduling, not compilation.
    let _ = sched.generate(contention_request("warmup", 2, 0));

    // Hot backlog first, then the cold tenant arrives behind it.
    let hot_handles: Vec<_> = (0..cfg.hot_n)
        .map(|i| sched.submit(contention_request("hot", cfg.max_tokens, i as u64)))
        .collect();
    let cold_handles: Vec<_> = (0..cfg.cold_n)
        .map(|i| sched.submit(contention_request("cold", cfg.max_tokens, 1000 + i as u64)))
        .collect();

    let completed = |handles: &[crate::server::scheduler::RequestHandle]| {
        handles.iter().filter(|h| h.recv().map(|r| r.error.is_none()).unwrap_or(false)).count()
    };
    let (hot_ok, cold_ok) = (completed(&hot_handles), completed(&cold_handles));

    let m = sched.metrics()?;
    let outcome = |tenant: &str, submitted: usize, ok: usize| {
        let (shed, p50, p99) = match m.tenants.get(tenant) {
            Some(t) => (
                t.shed,
                t.queue_wait.percentile(0.5) * 1e3,
                t.queue_wait.percentile(0.99) * 1e3,
            ),
            None => (0, 0.0, 0.0),
        };
        TenantOutcome {
            submitted,
            completed: ok,
            shed,
            queue_wait_p50_ms: p50,
            queue_wait_p99_ms: p99,
        }
    };
    let hot = outcome("hot", cfg.hot_n, hot_ok);
    let cold = outcome("cold", cfg.cold_n, cold_ok);
    sched.shutdown();
    Ok((hot, cold))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A mock-backed setup for fast tests regardless of artifacts.
    fn mock_setup() -> Setup {
        let (vocab, model) = json_mock(512);
        Setup {
            vocab,
            backend: Backend::Mock(model),
            backend_name: "mock",
            registry: EngineRegistry::new(REGISTRY_CAPACITY),
        }
    }

    #[test]
    fn setup_engine_is_cached() {
        let setup = mock_setup();
        let e1 = setup.engine("json").unwrap();
        let e2 = setup.engine("json").unwrap();
        assert!(Arc::ptr_eq(&e1, &e2), "registry must dedupe engine compiles");
        let s = setup.registry.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn eval_task_runs_all_methods() {
        let setup = mock_setup();
        for method in [
            Method::Unconstrained,
            Method::Domino { k: Lookahead::Infinite, spec: None, opportunistic: true },
            Method::Domino { k: Lookahead::K(0), spec: None, opportunistic: false },
            Method::Online { opportunistic: true },
        ] {
            let row = eval_task(&setup, &method, "gsm8k", 2, 48, 7).unwrap();
            assert_eq!(row.n, 2);
            assert!(row.toks_per_s >= 0.0, "{method:?}");
        }
    }

    #[test]
    fn drafted_method_runs_and_reports_acceptance() {
        let setup = mock_setup();
        let method = Method::Drafted { k: Lookahead::Infinite, draft: 6, prune: true };
        assert!(method.label().contains("pre-prune"));
        let row = eval_throughput(&setup, &method, "gsm8k", 2, 48, 3).unwrap();
        assert!(row.tokens > 0);
        assert!(
            row.spec_accepted > 0 && row.spec_accepted <= row.spec_proposed,
            "warmed prior must draft on the template-like gsm8k grammar: {row:?}"
        );
    }

    #[test]
    fn throughput_runs() {
        let setup = mock_setup();
        let row = eval_throughput(
            &setup,
            &Method::Domino { k: Lookahead::Infinite, spec: Some(8), opportunistic: true },
            "json",
            2,
            32,
            3,
        )
        .unwrap();
        assert!(row.tokens > 0);
    }

    #[test]
    fn contention_scenario_reports_tenant_percentiles() {
        let cfg = ContentionConfig {
            hot_n: 8,
            cold_n: 2,
            cold_weight: 4,
            max_tokens: 8,
            ..Default::default()
        };
        let (hot, cold) = run_contention(&cfg).unwrap();
        assert_eq!((hot.completed, cold.completed), (8, 2), "{hot:?} {cold:?}");
        assert_eq!(hot.shed + cold.shed, 0, "deep queue must not shed");
        assert!(cold.queue_wait_p99_ms >= 0.0 && cold.queue_wait_p99_ms.is_finite());
        // Solo baseline shape: no hot lane at all.
        let solo = ContentionConfig { hot_n: 0, cold_n: 2, max_tokens: 8, ..cfg };
        let (hot, cold) = run_contention(&solo).unwrap();
        assert_eq!((hot.submitted, hot.completed), (0, 0));
        assert_eq!(cold.completed, 2);
    }

    #[test]
    fn schema_workload_runs_and_shares_one_engine() {
        let setup = mock_setup();
        let row = eval_throughput(
            &setup,
            &Method::Domino { k: Lookahead::Infinite, spec: None, opportunistic: true },
            "function_call",
            2,
            48,
            5,
        )
        .unwrap();
        assert!(row.tokens > 0);
        // Warmup + measured requests all reuse one schema compile.
        let s = setup.registry.stats();
        assert_eq!(s.misses, 1, "{s:?}");
        assert_eq!(
            workload_spec("json"),
            crate::constraint::ConstraintSpec::builtin("json"),
            "builtin names pass through untouched"
        );
    }
}
