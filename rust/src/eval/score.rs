//! Scoring: well-formedness + answer extraction + accuracy.

use super::workload::{MathTask, NerTask};
use crate::util::Json;

/// Does the output parse as the expected structured format?
/// Unconstrained models may ramble after a valid value — like the paper,
/// we accept a valid *prefix* for unconstrained output but require the
/// whole string to parse when a constraint claims to enforce it.
pub fn well_formed_json(text: &str, strict: bool) -> bool {
    if strict {
        Json::parse(text.trim()).is_ok()
    } else {
        Json::parse_prefix(text).is_ok()
    }
}

/// Extract the `answer` field of the GSM8K schema from (possibly noisy)
/// output.
pub fn extract_answer(text: &str) -> Option<i64> {
    let (v, _) = Json::parse_prefix(text).ok()?;
    let a = v.get("answer")?.as_f64()?;
    Some(a as i64)
}

/// GSM8K-style accuracy: parsed answer equals gold.
pub fn math_correct(task: &MathTask, output: &str) -> bool {
    extract_answer(output) == Some(task.answer)
}

/// CoNLL-style scoring: exact-match F1 over (entity, type) pairs;
/// `accuracy` in Table 2 terms = exact set match.
pub fn ner_f1(task: &NerTask, output: &str) -> (f64, bool) {
    let Some((v, _)) = Json::parse_prefix(output).ok() else {
        return (0.0, false);
    };
    let Some(ents) = v.get("entities").and_then(|e| e.as_arr()) else {
        return (0.0, false);
    };
    let got: Vec<(String, String)> = ents
        .iter()
        .filter_map(|e| {
            Some((
                e.get("entity")?.as_str()?.to_string(),
                e.get("type")?.as_str()?.to_string(),
            ))
        })
        .collect();
    let gold = &task.entities;
    let tp = got.iter().filter(|g| gold.contains(g)).count() as f64;
    if got.is_empty() || gold.is_empty() {
        return (0.0, false);
    }
    let p = tp / got.len() as f64;
    let r = tp / gold.len() as f64;
    let f1 = if p + r == 0.0 { 0.0 } else { 2.0 * p * r / (p + r) };
    let exact = got.len() == gold.len() && tp as usize == gold.len();
    (f1, exact)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn math_task() -> MathTask {
        MathTask { question: "2+2?".into(), answer: 4 }
    }

    #[test]
    fn extracts_answers() {
        let out = r#"{"thoughts": [{"step": "add", "calculation": "2 + 2", "result": 4}], "answer": 4}"#;
        assert!(math_correct(&math_task(), out));
        assert!(!math_correct(&math_task(), r#"{"answer": 5}"#));
        assert!(!math_correct(&math_task(), "not json"));
        // Trailing rambles accepted (unconstrained case).
        assert!(math_correct(&math_task(), r#"{"answer": 4} and then some text"#));
    }

    #[test]
    fn well_formedness_modes() {
        assert!(well_formed_json(r#"{"a": 1}"#, true));
        assert!(!well_formed_json(r#"{"a": 1} extra"#, true));
        assert!(well_formed_json(r#"{"a": 1} extra"#, false));
        assert!(!well_formed_json("{", false));
    }

    #[test]
    fn ner_scoring() {
        let task = NerTask {
            sentence: "Tom Smith visited Paris.".into(),
            entities: vec![("Tom Smith".into(), "PER".into()), ("Paris".into(), "LOC".into())],
        };
        let perfect =
            r#"{"entities": [{"entity": "Tom Smith", "type": "PER"}, {"entity": "Paris", "type": "LOC"}]}"#;
        let (f1, exact) = ner_f1(&task, perfect);
        assert!((f1 - 1.0).abs() < 1e-9 && exact);
        let partial = r#"{"entities": [{"entity": "Tom Smith", "type": "PER"}]}"#;
        let (f1, exact) = ner_f1(&task, partial);
        assert!(f1 > 0.5 && f1 < 1.0 && !exact);
        let (f1, exact) = ner_f1(&task, "garbage");
        assert_eq!(f1, 0.0);
        assert!(!exact);
    }
}
