//! Synthetic task + prompt generators (rust mirror of
//! `python/compile/data.py` — same templates, fresh samples).

use crate::util::Rng;

pub const NAMES: &[&str] =
    &["Tom", "Anna", "Ben", "Mia", "Sam", "Lily", "Max", "Ruth", "Ivan", "Nora"];
pub const ITEMS: &[&str] =
    &["apples", "books", "coins", "pens", "cards", "shells", "stamps", "rocks"];
pub const CITIES: &[&str] =
    &["Paris", "Zurich", "Boston", "Tokyo", "Oslo", "Madrid", "Cairo", "Lima"];
pub const ORGS: &[&str] =
    &["Acme Corp", "Globex", "Initech", "Umbrella", "Stark Labs", "Wayne Co"];
pub const SURNAMES: &[&str] =
    &["Smith", "Doe", "Chen", "Garcia", "Patel", "Novak", "Kim", "Rossi"];

pub const GSM8K_PROMPT_PREFIX: &str = "Q: ";
pub const GSM8K_PROMPT_SUFFIX: &str = "\nA: ";
pub const CONLL_PROMPT_PREFIX: &str = "Sentence: ";
pub const CONLL_PROMPT_SUFFIX: &str = "\nEntities: ";

/// A math word problem with a known integer answer.
#[derive(Clone, Debug)]
pub struct MathTask {
    pub question: String,
    pub answer: i64,
}

impl MathTask {
    pub fn prompt(&self) -> String {
        format!("{GSM8K_PROMPT_PREFIX}{}{GSM8K_PROMPT_SUFFIX}", self.question)
    }
}

/// Sample one GSM8K-style task (same three templates as data.py).
pub fn math_task(rng: &mut Rng) -> MathTask {
    let name = *rng.choose(NAMES);
    let item = *rng.choose(ITEMS);
    match rng.below(3) {
        0 => {
            let a = rng.range(2, 12);
            let b = rng.range(2, 12);
            MathTask {
                question: format!(
                    "{name} has {a} {item} and buys {b} more. How many {item} does {name} have now?"
                ),
                answer: a + b,
            }
        }
        1 => {
            let a = rng.range(4, 15);
            let b = rng.range(1, a - 1);
            MathTask {
                question: format!(
                    "{name} has {a} {item} and gives away {b}. How many {item} are left?"
                ),
                answer: a - b,
            }
        }
        _ => {
            let a = rng.range(2, 6);
            let b = rng.range(2, 6);
            MathTask {
                question: format!(
                    "{name} has {a} bags with {b} {item} each. How many {item} in total?"
                ),
                answer: a * b,
            }
        }
    }
}

/// A NER task with known gold entities.
#[derive(Clone, Debug)]
pub struct NerTask {
    pub sentence: String,
    /// (entity text, type) — types: PER/LOC/ORG.
    pub entities: Vec<(String, String)>,
}

impl NerTask {
    pub fn prompt(&self) -> String {
        format!("{CONLL_PROMPT_PREFIX}{}{CONLL_PROMPT_SUFFIX}", self.sentence)
    }
}

pub fn ner_task(rng: &mut Rng) -> NerTask {
    let person = format!("{} {}", rng.choose(NAMES), rng.choose(SURNAMES));
    let city = rng.choose(CITIES).to_string();
    let org = rng.choose(ORGS).to_string();
    match rng.below(3) {
        0 => NerTask {
            sentence: format!("{person} works at {org} in {city}."),
            entities: vec![
                (person, "PER".into()),
                (org, "ORG".into()),
                (city, "LOC".into()),
            ],
        },
        1 => NerTask {
            sentence: format!("{person} visited {city} last week."),
            entities: vec![(person, "PER".into()), (city, "LOC".into())],
        },
        _ => NerTask {
            sentence: format!("{org} opened an office in {city}."),
            entities: vec![(org, "ORG".into()), (city, "LOC".into())],
        },
    }
}

/// A realistic function-calling request schema — the shape tool-use APIs
/// constrain assistant output to, and the schema workload used by the
/// `function_call` eval rows, `benches/schema_compile.rs` and
/// `tests/integration_jsonschema.rs`. Leaf types are mostly closed
/// (enums, digit-exact integer bounds) so constrained mock decodes stay
/// schema-valid under the strict validator.
pub const FUNCTION_CALL_SCHEMA: &str = r#"{
  "type": "object",
  "additionalProperties": false,
  "required": ["name", "arguments"],
  "properties": {
    "name": {"enum": ["get_weather", "search_flights", "send_email"]},
    "arguments": {
      "type": "object",
      "additionalProperties": false,
      "required": ["city", "units"],
      "properties": {
        "city": {"type": "string", "pattern": "[A-Za-z][A-Za-z ]{0,23}"},
        "units": {"enum": ["celsius", "fahrenheit"]},
        "days": {"type": "integer", "minimum": 1, "maximum": 9}
      }
    },
    "confidence": {"type": "number"}
  }
}"#;

/// Free-format prompts per grammar (Table 3 workloads; App. C "prompts
/// used for generation" adapted to the synthetic corpus conventions).
pub fn format_prompt(grammar: &str, rng: &mut Rng) -> String {
    match grammar {
        "json" => "A person encoded as JSON object:\n".to_string(),
        "gsm8k" => math_task(rng).prompt(),
        "conll" => ner_task(rng).prompt(),
        "xml" => "An XML file describing a person:\n".to_string(),
        "c" => "A simple C function:\n".to_string(),
        "template" => "A character profile for an RPG game in JSON format:\n".to_string(),
        "function_call" => "A tool call encoded as a JSON object:\n".to_string(),
        _ => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn math_tasks_are_solvable() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let t = math_task(&mut rng);
            assert!(t.answer > 0, "{t:?}");
            assert!(t.question.contains("How many"));
        }
    }

    #[test]
    fn ner_entities_appear_in_sentence() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let t = ner_task(&mut rng);
            for (e, ty) in &t.entities {
                assert!(t.sentence.contains(e.as_str()), "{t:?}");
                assert!(["PER", "LOC", "ORG"].contains(&ty.as_str()));
            }
        }
    }

    #[test]
    fn function_call_schema_compiles() {
        // The schema workload must stay inside the jsonschema subset.
        let cfg = crate::grammar::jsonschema::compile(FUNCTION_CALL_SCHEMA).unwrap();
        assert!(cfg.num_terminals() > 0);
        let mut rng = Rng::new(4);
        assert!(format_prompt("function_call", &mut rng).contains("tool call"));
    }

    #[test]
    fn prompts_match_training_convention() {
        // The exact prompt wrappers the training corpus used — a mismatch
        // here silently destroys model accuracy.
        let mut rng = Rng::new(3);
        let p = math_task(&mut rng).prompt();
        assert!(p.starts_with("Q: ") && p.ends_with("\nA: "));
        let p = ner_task(&mut rng).prompt();
        assert!(p.starts_with("Sentence: ") && p.ends_with("\nEntities: "));
    }
}
