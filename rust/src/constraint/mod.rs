//! The first-class constraint pipeline.
//!
//! DOMINO's headline speed comes from moving work *offline* (§3.5–3.6:
//! scanner tables, subterminal trees, Earley tables) — but that only pays
//! off under load if the compiled [`Engine`](crate::domino::Engine) is
//! **reused** across requests. This module makes constraints cacheable,
//! shareable values instead of stringly-typed request fields:
//!
//! * [`ConstraintSpec`] — *what* constrains the output: a builtin grammar
//!   by name, inline EBNF, a JSON Schema, a regex, stop sequences, or
//!   nothing. Specs normalize and hash to a stable 64-bit fingerprint —
//!   the cache key. (Schema sources canonicalize through
//!   [`grammar::jsonschema`](crate::grammar::jsonschema), so key order
//!   and whitespace differences dedupe.)
//! * [`EngineRegistry`] (in [`registry`]) — a concurrent, content-hash-
//!   keyed cache of compiled engines with size-bounded LRU eviction and
//!   build deduplication: concurrent requests for the same grammar
//!   compile it exactly once, everyone else waits for that build.
//! * [`MaskCache`] + [`CachedChecker`] (in [`mask_cache`]) — state-keyed
//!   reuse of computed token masks across slots and requests. Structured
//!   output revisits the same `(α, β)` checker states (§3.6) constantly;
//!   a cached mask turns a tree traversal (or, for the online baseline, a
//!   full-vocabulary scan) into a hash lookup.
//! * [`ArtifactStore`] (in [`artifact`]) — persistent precompute: a
//!   compiled engine (plus the hot entries of its mask cache) snapshotted
//!   to a versioned, checksummed on-disk file, keyed by
//!   [`ConstraintSpec::build_fingerprint`], so a restarted process
//!   warm-starts instead of recompiling every grammar.
//! * [`StopChecker`] (in [`stop`]) — plain stop-sequence constraints with
//!   no grammar machinery at all.
//! * [`Constraint`] / [`Enforcement`] — the request-level pairing of a
//!   spec with *how* it is enforced (DOMINO lookahead-`k`, optionally
//!   speculative or full-mask, or the online full-vocab baseline).
//!
//! See `rust/DESIGN.md` for how the server, eval harness and benches
//! thread these types through.

pub mod artifact;
pub mod mask_cache;
pub mod registry;
pub mod stop;

pub use artifact::{ArtifactLoad, ArtifactStore, LoadedArtifact, MaskSeed};
pub use mask_cache::{CachedChecker, MaskCache, MaskCacheStats};
pub use registry::{EngineRegistry, RegistryStats};
pub use stop::StopChecker;

use crate::grammar::{builtin, jsonschema, parse_ebnf, Cfg, CfgBuilder, Symbol};
use anyhow::{bail, Context};

/// What a generation request is constrained by. Hashable/normalizable so
/// compiled artifacts can be cached by content ([`ConstraintSpec::fingerprint`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum ConstraintSpec {
    /// No constraint.
    #[default]
    Unconstrained,
    /// One of the paper's builtin evaluation grammars, by name
    /// (see [`builtin::GRAMMAR_NAMES`]).
    Builtin { name: String },
    /// Inline EBNF in the crate's grammar notation (see [`parse_ebnf`]).
    Ebnf { source: String },
    /// A JSON Schema document (source text), compiled through
    /// [`grammar::jsonschema`](crate::grammar::jsonschema). Unsupported
    /// keywords fail compilation with a path-annotated error — a schema
    /// never silently weakens into a looser constraint.
    JsonSchema { source: String },
    /// Output must be exactly one match of this regex (the crate's
    /// dialect, compiled to a single-terminal grammar).
    Regex { pattern: String },
    /// Free generation until any of these byte sequences appears in the
    /// output, then EOS is forced.
    Stop { sequences: Vec<String> },
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

impl ConstraintSpec {
    pub fn builtin(name: impl Into<String>) -> ConstraintSpec {
        ConstraintSpec::Builtin { name: name.into() }
    }

    pub fn ebnf(source: impl Into<String>) -> ConstraintSpec {
        ConstraintSpec::Ebnf { source: source.into() }
    }

    /// A JSON Schema constraint. The source is canonicalized eagerly
    /// (sorted keys, no insignificant whitespace) so the repeated
    /// `fingerprint()` calls on the serving path (shard routing, registry
    /// keying) re-parse only the compact canonical text, and differently
    /// spelled copies of one schema are byte-equal from the start.
    /// Unparseable sources are kept verbatim — `to_cfg` reports the real
    /// error when compilation is attempted.
    pub fn json_schema(source: impl Into<String>) -> ConstraintSpec {
        let source = source.into();
        let source =
            crate::grammar::jsonschema::canonical_source(&source).unwrap_or(source);
        ConstraintSpec::JsonSchema { source }
    }

    pub fn regex(pattern: impl Into<String>) -> ConstraintSpec {
        ConstraintSpec::Regex { pattern: pattern.into() }
    }

    pub fn stop(sequences: Vec<String>) -> ConstraintSpec {
        ConstraintSpec::Stop { sequences }
    }

    /// Canonical form: builtin names are trimmed + lowercased, EBNF
    /// sources and regex patterns are trimmed, and JSON Schema sources
    /// canonicalize structurally (sorted keys, no insignificant
    /// whitespace) so two spellings of the same schema share one
    /// compiled engine. Two specs with equal normalized forms share one
    /// compiled engine.
    pub fn normalized(&self) -> ConstraintSpec {
        match self {
            ConstraintSpec::Unconstrained => ConstraintSpec::Unconstrained,
            ConstraintSpec::Builtin { name } => {
                ConstraintSpec::Builtin { name: name.trim().to_ascii_lowercase() }
            }
            ConstraintSpec::Ebnf { source } => {
                ConstraintSpec::Ebnf { source: source.trim().to_string() }
            }
            ConstraintSpec::JsonSchema { source } => ConstraintSpec::JsonSchema {
                // Unparseable sources normalize textually; `to_cfg`
                // reports the real error when compilation is attempted.
                source: crate::grammar::jsonschema::canonical_source(source)
                    .unwrap_or_else(|_| source.trim().to_string()),
            },
            ConstraintSpec::Regex { pattern } => {
                ConstraintSpec::Regex { pattern: pattern.trim().to_string() }
            }
            ConstraintSpec::Stop { sequences } => {
                ConstraintSpec::Stop { sequences: sequences.clone() }
            }
        }
    }

    /// Does this spec compile to a grammar [`Engine`](crate::domino::Engine)?
    pub fn is_grammar_backed(&self) -> bool {
        matches!(
            self,
            ConstraintSpec::Builtin { .. }
                | ConstraintSpec::Ebnf { .. }
                | ConstraintSpec::JsonSchema { .. }
                | ConstraintSpec::Regex { .. }
        )
    }

    /// Deterministic 64-bit content hash of the normalized spec (FNV-1a
    /// over a variant tag + length-prefixed fields). Stable across
    /// processes — usable as an on-disk or cross-node cache key too.
    pub fn fingerprint(&self) -> u64 {
        let norm = self.normalized();
        let mut h = FNV_OFFSET;
        let field = |h: &mut u64, bytes: &[u8]| {
            fnv1a(h, &(bytes.len() as u64).to_le_bytes());
            fnv1a(h, bytes);
        };
        match &norm {
            ConstraintSpec::Unconstrained => fnv1a(&mut h, &[0]),
            ConstraintSpec::Builtin { name } => {
                fnv1a(&mut h, &[1]);
                field(&mut h, name.as_bytes());
            }
            ConstraintSpec::Ebnf { source } => {
                fnv1a(&mut h, &[2]);
                field(&mut h, source.as_bytes());
            }
            ConstraintSpec::Regex { pattern } => {
                fnv1a(&mut h, &[3]);
                field(&mut h, pattern.as_bytes());
            }
            ConstraintSpec::Stop { sequences } => {
                fnv1a(&mut h, &[4]);
                for s in sequences {
                    field(&mut h, s.as_bytes());
                }
            }
            ConstraintSpec::JsonSchema { source } => {
                fnv1a(&mut h, &[5]);
                field(&mut h, source.as_bytes());
            }
        }
        h
    }

    /// The full *build* fingerprint: everything a compiled engine (and
    /// its on-disk artifact) depends on — the grammar content
    /// ([`Self::fingerprint`]), the vocabulary content
    /// ([`Vocab::fingerprint`](crate::tokenizer::Vocab::fingerprint)) and
    /// the lookahead configuration (`None` = ∞). This is the key used by
    /// [`EngineRegistry`] and the artifact store: folding the build
    /// parameters in means the same grammar under a retrained vocabulary
    /// or a different lookahead depth can never collide with (or serve) a
    /// stale build.
    pub fn build_fingerprint(&self, vocab_fingerprint: u64, k: Option<u32>) -> u64 {
        let mut h = self.fingerprint();
        fnv1a(&mut h, &vocab_fingerprint.to_le_bytes());
        match k {
            None => fnv1a(&mut h, &[0xFF]),
            Some(k) => {
                fnv1a(&mut h, &[0x01]);
                fnv1a(&mut h, &k.to_le_bytes());
            }
        }
        h
    }

    /// Short human-readable tag for logs, metrics and artifact headers
    /// (NOT a key — use the fingerprints for identity).
    pub fn label(&self) -> String {
        match self.normalized() {
            ConstraintSpec::Unconstrained => "unconstrained".to_string(),
            ConstraintSpec::Builtin { name } => format!("builtin:{name}"),
            ConstraintSpec::Ebnf { .. } => format!("ebnf:{:016x}", self.fingerprint()),
            ConstraintSpec::JsonSchema { .. } => {
                format!("jsonschema:{:016x}", self.fingerprint())
            }
            ConstraintSpec::Regex { pattern } => {
                let mut p: String = pattern.chars().take(32).collect();
                if p.len() < pattern.len() {
                    p.push('…');
                }
                format!("regex:{p}")
            }
            ConstraintSpec::Stop { sequences } => format!("stop:{}", sequences.len()),
        }
    }

    /// Compile the normalized spec to the CFG DOMINO consumes. Errors for
    /// specs with no grammar ([`Unconstrained`](ConstraintSpec::Unconstrained),
    /// [`Stop`](ConstraintSpec::Stop)).
    pub fn to_cfg(&self) -> crate::Result<Cfg> {
        match self.normalized() {
            ConstraintSpec::Unconstrained | ConstraintSpec::Stop { .. } => {
                bail!("constraint {:?} is not grammar-backed", self)
            }
            ConstraintSpec::Builtin { name } => builtin::by_name(&name).with_context(|| {
                format!(
                    "unknown builtin grammar `{name}` (known: {})",
                    builtin::GRAMMAR_NAMES.join(", ")
                )
            }),
            ConstraintSpec::Ebnf { source } => {
                parse_ebnf(&source).context("parsing inline EBNF constraint")
            }
            ConstraintSpec::JsonSchema { source } => {
                jsonschema::compile(&source).context("compiling JSON Schema constraint")
            }
            ConstraintSpec::Regex { pattern } => regex_cfg(&pattern),
        }
    }
}

/// A regex constraint as a single-terminal grammar: `root ::= /pattern/`.
fn regex_cfg(pattern: &str) -> crate::Result<Cfg> {
    // Pre-validate for a focused error (and to reject ε: nullable
    // terminals are illegal in the scanner split — optionality belongs to
    // the CFG, see grammar::builtin's translation notes).
    let nfa = crate::regex::compile(pattern)
        .with_context(|| format!("compiling regex constraint /{pattern}/"))?;
    if nfa.accepts(b"") {
        bail!("regex constraint /{pattern}/ matches the empty string; anchor it to require at least one character");
    }
    let mut b = CfgBuilder::new();
    let root = b.nonterminal("root");
    let t = b.regex_term("pattern", pattern);
    b.production(root, vec![Symbol::T(t)]);
    b.build(root)
}

/// How a grammar-backed constraint is enforced on the hot path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Enforcement {
    /// DOMINO decoder over precomputed subterminal trees. `k = None` is
    /// lookahead-∞ (minimally invasive); `speculative = Some(s)` enables
    /// §3.6 count-based speculation with chunk size `s`; `draft = Some(d)`
    /// enables the draft lane (grammar-pruned multi-token proposals from
    /// the shared prior, depth capped at `d` and adapted online — see
    /// [`crate::domino::draft`]); `full_mask` computes the mask every step
    /// (Algorithm 1 verbatim) instead of opportunistically. `speculative`,
    /// `draft` and `full_mask` are mutually exclusive (the front ends
    /// reject the combinations).
    Domino { k: Option<u32>, speculative: Option<usize>, draft: Option<usize>, full_mask: bool },
    /// Online full-vocabulary baseline (llama.cpp/GCD-style): same masks
    /// as DOMINO at k = ∞, no precomputation.
    Online,
}

impl Default for Enforcement {
    fn default() -> Self {
        Enforcement::Domino { k: None, speculative: None, draft: None, full_mask: false }
    }
}

/// A request's constraint: *what* ([`ConstraintSpec`]) plus *how*
/// ([`Enforcement`]). The enforcement is ignored for specs that need no
/// grammar engine (`Unconstrained`, `Stop`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Constraint {
    pub spec: ConstraintSpec,
    pub enforcement: Enforcement,
}

impl Constraint {
    /// No constraint.
    pub fn none() -> Constraint {
        Constraint::default()
    }

    /// DOMINO enforcement at lookahead ∞, opportunistic masking.
    pub fn domino(spec: ConstraintSpec) -> Constraint {
        Constraint { spec, enforcement: Enforcement::default() }
    }

    /// Online full-vocab baseline enforcement.
    pub fn online(spec: ConstraintSpec) -> Constraint {
        Constraint { spec, enforcement: Enforcement::Online }
    }

    /// Stop-sequence constraint (no grammar engine involved).
    pub fn stop(sequences: Vec<String>) -> Constraint {
        Constraint::domino(ConstraintSpec::stop(sequences))
    }

    /// Set the DOMINO lookahead (`None` = ∞). No-op for [`Enforcement::Online`].
    pub fn with_lookahead(mut self, k: Option<u32>) -> Constraint {
        if let Enforcement::Domino { k: slot, .. } = &mut self.enforcement {
            *slot = k;
        }
        self
    }

    /// Enable §3.6 speculation with chunk size `s`. No-op for online.
    pub fn with_speculation(mut self, s: usize) -> Constraint {
        if let Enforcement::Domino { speculative, .. } = &mut self.enforcement {
            *speculative = Some(s);
        }
        self
    }

    /// Enable the draft lane with proposal depth capped at `k` (adapted
    /// online from the slot's acceptance rate). No-op for online.
    pub fn with_draft(mut self, k: usize) -> Constraint {
        if let Enforcement::Domino { draft, .. } = &mut self.enforcement {
            *draft = Some(k);
        }
        self
    }

    /// Compute the full mask every step (Algorithm 1 verbatim). No-op for
    /// online.
    pub fn with_full_mask(mut self) -> Constraint {
        if let Enforcement::Domino { full_mask, .. } = &mut self.enforcement {
            *full_mask = true;
        }
        self
    }

    /// Assemble a constraint from the front-end vocabulary shared by the
    /// TCP protocol and the CLI: a `method` string (`"unconstrained"` |
    /// `"domino"` | `"domino-full"` | `"online"`), an optional spec, the
    /// lookahead `k`, the speculation chunk size and the draft depth cap.
    /// One implementation so the wire protocol and CLI can never drift
    /// apart. Invalid combinations (e.g. `draft` with a non-`"domino"`
    /// method) are the front ends' job to reject *before* this call; here
    /// the non-domino arms simply ignore the knobs that don't apply.
    pub fn from_parts(
        method: &str,
        spec: Option<ConstraintSpec>,
        k: Option<u32>,
        speculative: Option<usize>,
        draft: Option<usize>,
    ) -> Constraint {
        match (method, spec) {
            ("unconstrained", _) | (_, None) => Constraint::none(),
            ("online", Some(spec)) => Constraint::online(spec),
            ("domino-full", Some(spec)) => {
                Constraint::domino(spec).with_lookahead(k).with_full_mask()
            }
            (_, Some(spec)) => {
                let mut c = Constraint::domino(spec).with_lookahead(k);
                if let Some(s) = speculative {
                    c = c.with_speculation(s);
                }
                if let Some(d) = draft {
                    c = c.with_draft(d);
                }
                c
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_is_content_keyed() {
        let a = ConstraintSpec::builtin("json");
        let b = ConstraintSpec::builtin("json");
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), ConstraintSpec::builtin("gsm8k").fingerprint());
    }

    #[test]
    fn fingerprint_normalizes() {
        assert_eq!(
            ConstraintSpec::builtin("  JSON ").fingerprint(),
            ConstraintSpec::builtin("json").fingerprint()
        );
        assert_eq!(
            ConstraintSpec::ebnf("root ::= \"a\"\n").fingerprint(),
            ConstraintSpec::ebnf("root ::= \"a\"").fingerprint()
        );
        assert_eq!(
            ConstraintSpec::regex(" [0-9]+ ").fingerprint(),
            ConstraintSpec::regex("[0-9]+").fingerprint()
        );
    }

    #[test]
    fn fingerprint_separates_variants_and_fields() {
        // Same payload, different constraint kind → different key.
        let payloads = [
            ConstraintSpec::ebnf("x"),
            ConstraintSpec::regex("x"),
            ConstraintSpec::builtin("x"),
            ConstraintSpec::json_schema("x"),
        ];
        for (i, a) in payloads.iter().enumerate() {
            for b in payloads.iter().skip(i + 1) {
                assert_ne!(a.fingerprint(), b.fingerprint(), "{a:?} vs {b:?}");
            }
        }
        // Length-prefixed fields: ["a","b"] must differ from ["ab"].
        assert_ne!(
            ConstraintSpec::stop(vec!["a".into(), "b".into()]).fingerprint(),
            ConstraintSpec::stop(vec!["ab".into()]).fingerprint()
        );
    }

    #[test]
    fn build_fingerprint_separates_build_parameters() {
        let spec = ConstraintSpec::builtin("json");
        // Same grammar, different vocab → different build.
        assert_ne!(spec.build_fingerprint(1, None), spec.build_fingerprint(2, None));
        // Same grammar + vocab, different lookahead → different build.
        assert_ne!(spec.build_fingerprint(1, None), spec.build_fingerprint(1, Some(0)));
        assert_ne!(spec.build_fingerprint(1, Some(0)), spec.build_fingerprint(1, Some(1)));
        // Deterministic and normalization-aware, like `fingerprint`.
        assert_eq!(
            ConstraintSpec::builtin(" JSON ").build_fingerprint(7, Some(2)),
            spec.build_fingerprint(7, Some(2))
        );
    }

    #[test]
    fn json_schema_fingerprint_ignores_key_order_and_whitespace() {
        let a = ConstraintSpec::json_schema(
            r#"{"type": "object", "properties": {"x": {"type": "null"}}}"#,
        );
        let b = ConstraintSpec::json_schema(
            "{ \"properties\" : {\"x\":{\"type\":\"null\"}},\n\t\"type\":\"object\" }",
        );
        assert_eq!(a.normalized(), b.normalized());
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.build_fingerprint(7, Some(2)), b.build_fingerprint(7, Some(2)));
        // Different schemas stay distinct.
        assert_ne!(
            a.fingerprint(),
            ConstraintSpec::json_schema(r#"{"type": "object"}"#).fingerprint()
        );
    }

    #[test]
    fn json_schema_compiles_and_errors_are_path_annotated() {
        let cfg = ConstraintSpec::json_schema(
            r#"{"type": "object", "required": ["ok"], "properties": {"ok": {"type": "boolean"}}}"#,
        )
        .to_cfg()
        .unwrap();
        assert!(cfg.num_terminals() > 0);
        let err = ConstraintSpec::json_schema(
            r#"{"type": "object", "properties": {"x": {"type": "number", "multipleOf": 3}}}"#,
        )
        .to_cfg()
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("#/properties/x/multipleOf"), "{msg}");
    }

    #[test]
    fn unknown_builtin_error_lists_known_grammars() {
        let err = ConstraintSpec::builtin("no-such-grammar").to_cfg().unwrap_err();
        let msg = format!("{err:#}");
        for name in builtin::GRAMMAR_NAMES {
            assert!(msg.contains(name), "missing `{name}` in: {msg}");
        }
    }

    #[test]
    fn labels_are_short_and_total() {
        assert_eq!(ConstraintSpec::builtin(" JSON ").label(), "builtin:json");
        assert!(ConstraintSpec::json_schema("{}").label().starts_with("jsonschema:"));
        assert_eq!(ConstraintSpec::Unconstrained.label(), "unconstrained");
        assert!(ConstraintSpec::ebnf("root ::= \"a\"").label().starts_with("ebnf:"));
        assert!(ConstraintSpec::regex(&"x".repeat(100)).label().len() < 50);
        assert_eq!(ConstraintSpec::stop(vec!["a".into()]).label(), "stop:1");
    }

    #[test]
    fn regex_spec_compiles_to_single_terminal_cfg() {
        let cfg = ConstraintSpec::regex("[0-9]{4}").to_cfg().unwrap();
        assert_eq!(cfg.num_terminals(), 1);
        let dfas = cfg.terminal_dfas().unwrap();
        assert!(dfas[0].accepts(b"1234"));
        assert!(!dfas[0].accepts(b"123"));
        assert!(!dfas[0].accepts(b"12345"));
    }

    #[test]
    fn nullable_regex_rejected() {
        assert!(ConstraintSpec::regex("[0-9]*").to_cfg().is_err());
    }

    #[test]
    fn non_grammar_specs_do_not_compile() {
        assert!(ConstraintSpec::Unconstrained.to_cfg().is_err());
        assert!(ConstraintSpec::stop(vec!["x".into()]).to_cfg().is_err());
    }

    #[test]
    fn ebnf_spec_compiles() {
        let cfg = ConstraintSpec::ebnf("root ::= \"ab\" | \"cd\"").to_cfg().unwrap();
        assert_eq!(cfg.num_terminals(), 2);
    }

    #[test]
    fn from_parts_covers_every_method() {
        let spec = || Some(ConstraintSpec::builtin("json"));
        assert_eq!(
            Constraint::from_parts("unconstrained", spec(), None, None, None),
            Constraint::none()
        );
        assert_eq!(
            Constraint::from_parts("domino", None, Some(1), Some(8), Some(6)),
            Constraint::none()
        );
        assert_eq!(
            Constraint::from_parts("online", spec(), Some(1), Some(8), None),
            Constraint::online(ConstraintSpec::builtin("json"))
        );
        assert_eq!(
            Constraint::from_parts("domino-full", spec(), Some(1), Some(8), None),
            Constraint::domino(ConstraintSpec::builtin("json"))
                .with_lookahead(Some(1))
                .with_full_mask(),
            "domino-full ignores speculation"
        );
        assert_eq!(
            Constraint::from_parts("domino", spec(), None, Some(8), None),
            Constraint::domino(ConstraintSpec::builtin("json")).with_speculation(8)
        );
        assert_eq!(
            Constraint::from_parts("domino", spec(), None, None, Some(6)),
            Constraint::domino(ConstraintSpec::builtin("json")).with_draft(6)
        );
    }

    #[test]
    fn builders_compose() {
        let c = Constraint::domino(ConstraintSpec::builtin("json"))
            .with_lookahead(Some(2))
            .with_speculation(8);
        assert_eq!(
            c.enforcement,
            Enforcement::Domino { k: Some(2), speculative: Some(8), draft: None, full_mask: false }
        );
        let c = Constraint::domino(ConstraintSpec::builtin("json")).with_draft(4);
        assert_eq!(
            c.enforcement,
            Enforcement::Domino { k: None, speculative: None, draft: Some(4), full_mask: false }
        );
        let c = Constraint::online(ConstraintSpec::builtin("json")).with_full_mask();
        assert_eq!(c.enforcement, Enforcement::Online, "online ignores domino knobs");
        assert_eq!(Constraint::none().spec, ConstraintSpec::Unconstrained);
    }
}
