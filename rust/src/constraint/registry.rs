//! The shared engine registry: compile each grammar once, serve it
//! everywhere — and, with an [`ArtifactStore`] attached, keep that work
//! across process restarts.
//!
//! A compiled [`Engine`] is the expensive artifact of the whole system —
//! scanner union NFA, vocabulary-aligned subterminal trees (Algorithm 2),
//! Earley tables. The paper's premise is that this cost is paid *offline*
//! (§3.5, Table: 1–20 s per grammar); a serving path that rebuilds it per
//! request forfeits the entire headline win. The registry makes the
//! amortization real:
//!
//! * keyed by **build fingerprint** ([`ConstraintSpec::build_fingerprint`]:
//!   grammar content × vocabulary content × lookahead config), so a
//!   builtin name, an inline EBNF body and a regex all cache uniformly —
//!   and the same grammar under different build parameters can never
//!   collide (or, on disk, serve a stale build);
//! * **build-deduplicated**: when N requests race on an uncached grammar,
//!   one thread compiles, the rest block on that build and share the
//!   result (no thundering-herd compile);
//! * **load-or-build**: with a store attached, a miss first tries the
//!   on-disk artifact (deserialize + validate version/checksum/vocab
//!   fingerprints); only a miss or an invalid artifact compiles from
//!   source, and fresh compiles are written back atomically. A corrupt
//!   artifact is *never* an error — it increments `artifact_invalid` and
//!   falls back to a clean rebuild;
//! * **warm-startable**: [`EngineRegistry::warm_start`] scans the store
//!   once per process and registers every artifact valid for the live
//!   vocabulary, so a restarted server answers its first constrained
//!   request with zero compile latency;
//! * **size-bounded** with LRU eviction — an adversarial stream of
//!   distinct inline grammars degrades to recompilation, not unbounded
//!   memory;
//! * each entry carries the engine's shared [`MaskCache`], so state-keyed
//!   mask reuse follows the engine around for free (artifacts persist the
//!   hot entries; [`EngineRegistry::flush_artifacts`] re-saves them);
//! * counters (hits/misses/evictions/coalesced builds/compile-time and
//!   artifact hits/misses/invalid + warm-start timing) are exported
//!   through `server::metrics` for amortization reporting.

use super::artifact::{ArtifactLoad, ArtifactStore, MaskSeed};
use super::mask_cache::{MaskCache, MaskCacheStats};
use super::ConstraintSpec;
use crate::domino::decoder::Engine;
use crate::tokenizer::Vocab;
use anyhow::bail;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Per-engine mask-cache capacity (distinct `(variant, state)` entries).
const MASK_CACHE_CAPACITY: usize = 4096;

/// Hot mask entries persisted per artifact by [`EngineRegistry::flush_artifacts`].
const PERSIST_MASK_LIMIT: usize = 512;

/// Registry counters, readable without blocking builds.
#[derive(Clone, Debug, Default)]
pub struct RegistryStats {
    /// Lookups served from the in-memory cache.
    pub hits: u64,
    /// Lookups not in memory (each either loads an artifact or compiles).
    pub misses: u64,
    /// Entries dropped by LRU eviction.
    pub evictions: u64,
    /// Lookups that waited on a concurrent build instead of compiling.
    pub coalesced: u64,
    /// Total wall time spent compiling engines, milliseconds.
    pub compile_ms: u64,
    /// Engines deserialized from the artifact store (on-demand loads and
    /// warm-start scans).
    pub artifact_hits: u64,
    /// Store lookups that found no artifact (the compile then writes one
    /// back).
    pub artifact_misses: u64,
    /// Artifacts rejected (truncated / checksum / version / vocab
    /// fingerprint mismatch) — each fell back to a clean rebuild.
    pub artifact_invalid: u64,
    /// Engines registered by the warm-start scan.
    pub warm_loaded: u64,
    /// Wall time of the warm-start scan, milliseconds.
    pub warm_start_ms: u64,
    /// Live entries (hot + warm tiers).
    pub entries: usize,
    /// Hot-tier entries (engine + mask cache resident).
    pub hot_entries: usize,
    /// Warm-tier entries (engine resident, mask cache dropped).
    pub warm_entries: usize,
    /// Cold-tier entries (artifact indexed on disk, loaded on demand).
    pub cold_entries: usize,
}

struct Entry {
    engine: Arc<Engine>,
    masks: Arc<MaskCache>,
    /// Human tag for diagnostics and artifact re-saves.
    label: String,
    tick: u64,
}

/// A hot-tier entry demoted by LRU pressure: the compiled engine is kept
/// (compiling is the expensive part) but its mask cache is dropped — a
/// warm hit pays mask recomputation, never a recompile.
struct WarmEntry {
    engine: Arc<Engine>,
    label: String,
    tick: u64,
}

enum BuildState {
    Pending,
    Ready(Arc<Engine>, Arc<MaskCache>),
    Failed(String),
}

struct Build {
    state: Mutex<BuildState>,
    cv: Condvar,
}

struct Inner {
    map: HashMap<u64, Entry>,
    /// Engines demoted from the hot tier, mask caches dropped.
    warm: HashMap<u64, WarmEntry>,
    /// Build fingerprints known to exist on disk but not resident — the
    /// O(index) warm-start scan parks everything past the hot capacity
    /// here, and warm-tier evictions return keys here when a store is
    /// attached. A cold hit is an on-demand artifact load.
    cold: HashSet<u64>,
    building: HashMap<u64, Arc<Build>>,
    tick: u64,
    /// Mask-cache counters of evicted/cleared entries, folded in so the
    /// aggregate in [`EngineRegistry::mask_stats`] is monotonic (metrics
    /// consumers compute deltas between snapshots).
    retired_masks: MaskCacheStats,
}

/// A concurrent, content-hash-keyed cache of compiled grammar engines,
/// optionally backed by a persistent [`ArtifactStore`].
pub struct EngineRegistry {
    capacity: usize,
    /// Warm-tier bound: engines demoted from the hot tier are kept (sans
    /// mask cache) up to this many before being dropped entirely.
    warm_capacity: usize,
    store: Option<ArtifactStore>,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    coalesced: AtomicU64,
    compile_ms: AtomicU64,
    artifact_hits: AtomicU64,
    artifact_misses: AtomicU64,
    artifact_invalid: AtomicU64,
    warm_loaded: AtomicU64,
    warm_start_ms: AtomicU64,
    /// Set by the first `warm_start` call; later calls are no-ops so every
    /// shard init can invoke it unconditionally.
    warmed: AtomicBool,
    /// When set, cache misses compile with [`Engine::compile_lazy`]:
    /// near-instant builds whose scanner DFAs and subterminal trees
    /// materialize per visited state. Artifact loads are unaffected (they
    /// already carry dense tables).
    lazy_build: AtomicBool,
}

impl EngineRegistry {
    pub fn new(capacity: usize) -> Arc<EngineRegistry> {
        Self::build(capacity, capacity * 4, None)
    }

    /// A registry whose misses consult (and whose compiles write back to)
    /// a persistent artifact store.
    pub fn with_store(capacity: usize, store: ArtifactStore) -> Arc<EngineRegistry> {
        Self::build(capacity, capacity * 4, Some(store))
    }

    /// Full tier control: `capacity` hot entries (engine + mask cache),
    /// `warm_capacity` demoted engines kept without mask caches (0
    /// disables the warm tier — eviction drops engines outright, the
    /// pre-tier behavior).
    pub fn with_tiers(
        capacity: usize,
        warm_capacity: usize,
        store: Option<ArtifactStore>,
    ) -> Arc<EngineRegistry> {
        Self::build(capacity, warm_capacity, store)
    }

    fn build(
        capacity: usize,
        warm_capacity: usize,
        store: Option<ArtifactStore>,
    ) -> Arc<EngineRegistry> {
        assert!(capacity >= 1, "registry needs capacity >= 1");
        Arc::new(EngineRegistry {
            capacity,
            warm_capacity,
            store,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                warm: HashMap::new(),
                cold: HashSet::new(),
                building: HashMap::new(),
                tick: 0,
                retired_masks: MaskCacheStats::default(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            compile_ms: AtomicU64::new(0),
            artifact_hits: AtomicU64::new(0),
            artifact_misses: AtomicU64::new(0),
            artifact_invalid: AtomicU64::new(0),
            warm_loaded: AtomicU64::new(0),
            warm_start_ms: AtomicU64::new(0),
            warmed: AtomicBool::new(false),
            lazy_build: AtomicBool::new(false),
        })
    }

    /// Switch cache-miss compiles between eager (default) and lazy
    /// ([`Engine::compile_lazy`]). Takes effect for subsequent misses;
    /// already-cached engines keep whichever mode built them.
    pub fn set_lazy_build(&self, on: bool) {
        self.lazy_build.store(on, Ordering::Relaxed);
    }

    pub fn lazy_build(&self) -> bool {
        self.lazy_build.load(Ordering::Relaxed)
    }

    /// The attached artifact store, if any.
    pub fn store(&self) -> Option<&ArtifactStore> {
        self.store.as_ref()
    }

    /// The cache key: the spec's build fingerprint over the vocabulary
    /// *content* hash and the lookahead config (`None` = ∞). Stable
    /// across processes — the same key names the on-disk artifact.
    pub fn key_for(spec: &ConstraintSpec, vocab: &Arc<Vocab>, k: Option<u32>) -> u64 {
        spec.build_fingerprint(vocab.fingerprint(), k)
    }

    /// Fetch the compiled engine for `(spec, k)`, loading it from the
    /// artifact store or compiling it (exactly once, even under
    /// concurrency) on a miss. Returns the engine plus its shared mask
    /// cache.
    pub fn get_or_compile(
        &self,
        spec: &ConstraintSpec,
        vocab: &Arc<Vocab>,
        k: Option<u32>,
    ) -> crate::Result<(Arc<Engine>, Arc<MaskCache>)> {
        if !spec.is_grammar_backed() {
            bail!("constraint {spec:?} has no grammar engine");
        }
        let key = Self::key_for(spec, vocab, k);

        let build = {
            let mut inner = self.inner.lock().expect("registry lock");
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.map.get_mut(&key) {
                e.tick = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok((e.engine.clone(), e.masks.clone()));
            }
            if let Some(w) = inner.warm.remove(&key) {
                // Warm hit: the compiled engine was kept through its
                // demotion; promote it back to hot with a fresh mask
                // cache. Costs mask recomputation, never a recompile —
                // still an in-memory hit.
                self.hits.fetch_add(1, Ordering::Relaxed);
                let masks = Arc::new(MaskCache::new(MASK_CACHE_CAPACITY));
                let engine = w.engine.clone();
                self.insert_locked(&mut inner, key, w.engine, masks.clone(), w.label);
                return Ok((engine, masks));
            }
            if let Some(b) = inner.building.get(&key) {
                // Someone else is compiling (or loading) this grammar
                // right now: wait for their build instead of duplicating
                // it.
                let b = b.clone();
                drop(inner);
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                let mut st = b.state.lock().expect("build lock");
                loop {
                    match &*st {
                        BuildState::Pending => {}
                        BuildState::Ready(e, m) => return Ok((e.clone(), m.clone())),
                        BuildState::Failed(msg) => bail!("engine compile failed: {msg}"),
                    }
                    st = b.cv.wait(st).expect("build wait");
                }
            }
            let build =
                Arc::new(Build { state: Mutex::new(BuildState::Pending), cv: Condvar::new() });
            inner.building.insert(key, build.clone());
            build
        };

        // Miss: load or build outside the registry lock.
        self.misses.fetch_add(1, Ordering::Relaxed);
        let label = spec.label();
        let loaded: Option<(Arc<Engine>, Vec<MaskSeed>)> = match &self.store {
            None => None,
            Some(store) => match store.load(spec, vocab, k) {
                ArtifactLoad::Hit { engine, masks, .. } => {
                    self.artifact_hits.fetch_add(1, Ordering::Relaxed);
                    Some((engine, masks))
                }
                ArtifactLoad::Miss => {
                    self.artifact_misses.fetch_add(1, Ordering::Relaxed);
                    None
                }
                ArtifactLoad::Invalid { reason } => {
                    self.artifact_invalid.fetch_add(1, Ordering::Relaxed);
                    eprintln!("domino: artifact for {label} unusable ({reason}); rebuilding");
                    None
                }
            },
        };
        let from_store = loaded.is_some();
        let result: crate::Result<(Arc<Engine>, Vec<MaskSeed>)> = match loaded {
            Some(hit) => Ok(hit),
            None => {
                let t0 = Instant::now();
                let lazy = self.lazy_build.load(Ordering::Relaxed);
                let r = spec.to_cfg().and_then(|cfg| {
                    if lazy {
                        Engine::compile_lazy(cfg, vocab.clone())
                    } else {
                        Engine::compile(cfg, vocab.clone())
                    }
                });
                self.compile_ms.fetch_add(t0.elapsed().as_millis() as u64, Ordering::Relaxed);
                r.map(|engine| (engine, Vec::new()))
            }
        };

        match result {
            Ok((engine, seeds)) => {
                let masks = Arc::new(MaskCache::new(MASK_CACHE_CAPACITY));
                for s in seeds {
                    masks.put(s.variant, s.state, s.mask);
                }
                // Publish first: coalesced waiters and new lookups get
                // the engine before any disk work happens.
                self.insert_entry(key, engine.clone(), masks.clone(), label.clone());
                let mut st = build.state.lock().expect("build lock");
                *st = BuildState::Ready(engine.clone(), masks.clone());
                drop(st);
                build.cv.notify_all();
                {
                    let mut inner = self.inner.lock().expect("registry lock");
                    inner.building.remove(&key);
                }
                if !from_store && !engine.is_lazy() {
                    // Write-back: the next process boots warm. Only the
                    // thread that compiled pays the disk; failures
                    // degrade to cold starts, never to request errors.
                    // Lazy engines skip the immediate write-back — saving
                    // would force full materialization, defeating the
                    // deferred-compile point; `flush_artifacts` persists
                    // them (materialized) at shutdown instead.
                    if let Some(store) = &self.store {
                        if let Err(e) = store.save(spec, vocab, k, &engine, &[]) {
                            eprintln!("domino: artifact write-back for {label} failed: {e:#}");
                        }
                    }
                }
                Ok((engine, masks))
            }
            Err(e) => {
                {
                    let mut inner = self.inner.lock().expect("registry lock");
                    inner.building.remove(&key);
                }
                let mut st = build.state.lock().expect("build lock");
                *st = BuildState::Failed(format!("{e:#}"));
                drop(st);
                build.cv.notify_all();
                Err(e)
            }
        }
    }

    /// Register an engine under `key`, demoting LRU hot entries past
    /// capacity.
    fn insert_entry(&self, key: u64, engine: Arc<Engine>, masks: Arc<MaskCache>, label: String) {
        let mut inner = self.inner.lock().expect("registry lock");
        self.insert_locked(&mut inner, key, engine, masks, label);
    }

    /// [`Self::insert_entry`] with the registry lock already held.
    ///
    /// Hot-tier overflow demotes the LRU victim to the warm tier: its
    /// mask-cache counters are retired (the cache itself is dropped) but
    /// the compiled engine survives, so a re-request recomputes masks
    /// instead of recompiling. Warm-tier overflow drops the engine
    /// outright — with a store attached the key is parked in the cold set,
    /// since its artifact (written back at compile time) can be reloaded
    /// on demand. `evictions` counts hot-tier demotions, preserving the
    /// pre-tier meaning of "pushed out of the hot path by LRU pressure".
    fn insert_locked(
        &self,
        inner: &mut Inner,
        key: u64,
        engine: Arc<Engine>,
        masks: Arc<MaskCache>,
        label: String,
    ) {
        inner.tick += 1;
        let tick = inner.tick;
        inner.cold.remove(&key); // resident now, by definition not cold
        if inner.map.len() >= self.capacity && !inner.map.contains_key(&key) {
            let victim = inner.map.iter().min_by_key(|(_, e)| e.tick).map(|(k, _)| *k);
            if let Some(old) = victim {
                if let Some(entry) = inner.map.remove(&old) {
                    let mut s = entry.masks.stats();
                    s.entries = 0; // retired entries are no longer live
                    inner.retired_masks.merge(&s);
                    if self.warm_capacity > 0 {
                        if inner.warm.len() >= self.warm_capacity {
                            let wv = inner.warm.iter().min_by_key(|(_, e)| e.tick).map(|(k, _)| *k);
                            if let Some(wk) = wv {
                                inner.warm.remove(&wk);
                                if self.store.is_some() {
                                    inner.cold.insert(wk);
                                }
                            }
                        }
                        inner.warm.insert(
                            old,
                            WarmEntry { engine: entry.engine, label: entry.label, tick: entry.tick },
                        );
                    } else if self.store.is_some() {
                        inner.cold.insert(old);
                    }
                }
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        inner.map.insert(key, Entry { engine, masks, label, tick });
    }

    /// Scan the artifact store's *index* (fixed-size header prefixes,
    /// O(file count) — never O(corpus bytes)) and register engines valid
    /// for `vocab`: up to the hot capacity they are fully loaded so the
    /// first request for each is an in-memory hit; everything past that is
    /// parked in the cold set and loads on demand. Idempotent per process
    /// (only the first call scans; every shard init may invoke it
    /// unconditionally). Returns the number of engines loaded by *this*
    /// call.
    pub fn warm_start(&self, vocab: &Arc<Vocab>) -> usize {
        let Some(store) = &self.store else { return 0 };
        if self.warmed.swap(true, Ordering::SeqCst) {
            return 0;
        }
        let t0 = Instant::now();
        let (headers, invalid) = store.scan_index(vocab);
        self.artifact_invalid.fetch_add(invalid as u64, Ordering::Relaxed);
        let mut loaded = 0usize;
        for h in headers {
            let (resident, hot_full) = {
                let inner = self.inner.lock().expect("registry lock");
                (
                    inner.map.contains_key(&h.key) || inner.warm.contains_key(&h.key),
                    inner.map.len() >= self.capacity,
                )
            };
            if resident {
                continue;
            }
            if hot_full {
                // Past the hot bound: index only. A later request pays one
                // on-demand artifact load — still no compile.
                self.inner.lock().expect("registry lock").cold.insert(h.key);
                continue;
            }
            match store.load_keyed(h.key, vocab) {
                ArtifactLoad::Hit { engine, masks, label } => {
                    let cache = Arc::new(MaskCache::new(MASK_CACHE_CAPACITY));
                    for s in masks {
                        cache.put(s.variant, s.state, s.mask);
                    }
                    self.insert_entry(h.key, engine, cache, label);
                    loaded += 1;
                }
                ArtifactLoad::Invalid { reason } => {
                    // The index prefix looked fine but the body didn't
                    // verify; first real demand rebuilds from source.
                    self.artifact_invalid.fetch_add(1, Ordering::Relaxed);
                    eprintln!("domino: artifact {:016x} unusable ({reason}); skipped", h.key);
                }
                ArtifactLoad::Miss => {} // raced with a concurrent delete
            }
        }
        self.artifact_hits.fetch_add(loaded as u64, Ordering::Relaxed);
        self.warm_loaded.fetch_add(loaded as u64, Ordering::Relaxed);
        self.warm_start_ms.store(t0.elapsed().as_millis() as u64, Ordering::Relaxed);
        loaded
    }

    /// Re-save every cached engine's artifact including the current hot
    /// mask-cache entries (call at shutdown, or after warmup traffic):
    /// the next boot then starts with the masks this process paid for.
    /// Returns the number of artifacts written.
    pub fn flush_artifacts(&self) -> usize {
        let Some(store) = &self.store else { return 0 };
        let entries: Vec<_> = {
            let inner = self.inner.lock().expect("registry lock");
            inner
                .map
                .iter()
                .map(|(k, e)| {
                    (*k, e.label.clone(), e.engine.clone(), e.masks.hot_entries(PERSIST_MASK_LIMIT))
                })
                .collect()
        };
        let mut written = 0usize;
        for (key, label, engine, hot) in entries {
            let seeds: Vec<MaskSeed> = hot
                .into_iter()
                .map(|(variant, state, mask)| MaskSeed { variant, state, mask })
                .collect();
            match store.save_keyed(key, &label, &engine, &seeds) {
                Ok(_) => written += 1,
                Err(e) => eprintln!("domino: artifact flush for {label} failed: {e:#}"),
            }
        }
        written
    }

    /// Is this build's engine currently resident (no compile triggered)?
    /// True for both tiers: a warm hit promotes without recompiling.
    pub fn contains(&self, spec: &ConstraintSpec, vocab: &Arc<Vocab>, k: Option<u32>) -> bool {
        let key = Self::key_for(spec, vocab, k);
        let inner = self.inner.lock().expect("registry lock");
        inner.map.contains_key(&key) || inner.warm.contains_key(&key)
    }

    /// Resident engines (hot + warm tiers).
    pub fn len(&self) -> usize {
        let inner = self.inner.lock().expect("registry lock");
        inner.map.len() + inner.warm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every resident engine and the cold index (counters are kept;
    /// the dropped entries' mask-cache counters are folded into the
    /// retired aggregate).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().expect("registry lock");
        let entries: Vec<Entry> = inner.map.drain().map(|(_, e)| e).collect();
        for e in entries {
            let mut s = e.masks.stats();
            s.entries = 0;
            inner.retired_masks.merge(&s);
        }
        inner.warm.clear();
        inner.cold.clear();
    }

    pub fn stats(&self) -> RegistryStats {
        let (hot, warm, cold) = {
            let inner = self.inner.lock().expect("registry lock");
            (inner.map.len(), inner.warm.len(), inner.cold.len())
        };
        RegistryStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            compile_ms: self.compile_ms.load(Ordering::Relaxed),
            artifact_hits: self.artifact_hits.load(Ordering::Relaxed),
            artifact_misses: self.artifact_misses.load(Ordering::Relaxed),
            artifact_invalid: self.artifact_invalid.load(Ordering::Relaxed),
            warm_loaded: self.warm_loaded.load(Ordering::Relaxed),
            warm_start_ms: self.warm_start_ms.load(Ordering::Relaxed),
            entries: hot + warm,
            hot_entries: hot,
            warm_entries: warm,
            cold_entries: cold,
        }
    }

    /// Aggregate mask-cache counters: live entries plus a snapshot of
    /// every evicted/cleared entry's counters at retirement time, so the
    /// totals are monotonic across snapshots. (Hits an in-flight slot
    /// scores on an already-evicted engine's cache after its retirement
    /// snapshot are the one thing not counted.)
    pub fn mask_stats(&self) -> MaskCacheStats {
        let inner = self.inner.lock().expect("registry lock");
        let mut agg = inner.retired_masks.clone();
        for e in inner.map.values() {
            agg.merge(&e.masks.stats());
        }
        agg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer;

    fn vocab() -> Arc<Vocab> {
        Arc::new(tokenizer::bpe::synthetic_json_vocab(256))
    }

    #[test]
    fn hit_after_miss() {
        let v = vocab();
        let reg = EngineRegistry::new(4);
        let spec = ConstraintSpec::builtin("fig3");
        assert!(!reg.contains(&spec, &v, None));
        let (e1, _) = reg.get_or_compile(&spec, &v, None).unwrap();
        assert!(reg.contains(&spec, &v, None));
        let (e2, _) = reg.get_or_compile(&spec, &v, None).unwrap();
        assert!(Arc::ptr_eq(&e1, &e2), "second lookup must reuse the engine");
        let s = reg.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.artifact_hits, 0, "no store attached");
    }

    #[test]
    fn normalized_specs_share_an_entry() {
        let v = vocab();
        let reg = EngineRegistry::new(4);
        reg.get_or_compile(&ConstraintSpec::builtin("fig3"), &v, None).unwrap();
        reg.get_or_compile(&ConstraintSpec::builtin(" FIG3 "), &v, None).unwrap();
        let s = reg.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn distinct_vocabs_do_not_collide() {
        let v1 = vocab();
        let v2 = Arc::new(tokenizer::bpe::synthetic_json_vocab(320));
        let reg = EngineRegistry::new(4);
        let spec = ConstraintSpec::builtin("fig3");
        let (e1, _) = reg.get_or_compile(&spec, &v1, None).unwrap();
        let (e2, _) = reg.get_or_compile(&spec, &v2, None).unwrap();
        assert!(!Arc::ptr_eq(&e1, &e2));
        assert_eq!(reg.stats().misses, 2);
    }

    #[test]
    fn distinct_lookaheads_do_not_collide() {
        // Same grammar, different build parameter `k` → distinct entries
        // (their artifacts and speculation priors are k-specific).
        let v = vocab();
        let reg = EngineRegistry::new(4);
        let spec = ConstraintSpec::builtin("fig3");
        reg.get_or_compile(&spec, &v, None).unwrap();
        reg.get_or_compile(&spec, &v, Some(0)).unwrap();
        reg.get_or_compile(&spec, &v, Some(1)).unwrap();
        let s = reg.stats();
        assert_eq!((s.misses, s.entries), (3, 3));
        assert!(reg.contains(&spec, &v, Some(0)));
        assert!(!reg.contains(&spec, &v, Some(2)));
    }

    #[test]
    fn compile_failure_reported_and_not_cached() {
        let v = vocab();
        let reg = EngineRegistry::new(4);
        let bad = ConstraintSpec::builtin("no-such-grammar");
        assert!(reg.get_or_compile(&bad, &v, None).is_err());
        assert!(!reg.contains(&bad, &v, None));
        // A failed build must not wedge later lookups of the same key.
        assert!(reg.get_or_compile(&bad, &v, None).is_err());
    }

    #[test]
    fn lazy_build_flag_compiles_lazy_and_flush_materializes() {
        let dir = std::env::temp_dir()
            .join(format!("domino_registry_lazy_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let v = vocab();
        let spec = ConstraintSpec::builtin("fig3");
        {
            let reg = EngineRegistry::with_store(4, ArtifactStore::new(&dir).unwrap());
            reg.set_lazy_build(true);
            assert!(reg.lazy_build());
            let (engine, _) = reg.get_or_compile(&spec, &v, None).unwrap();
            assert!(engine.is_lazy());
            // No immediate write-back for lazy compiles…
            assert!(matches!(reg.store().unwrap().load(&spec, &v, None), ArtifactLoad::Miss));
            // …but the shutdown flush persists them, materialized.
            assert_eq!(reg.flush_artifacts(), 1);
        }
        let reg2 = EngineRegistry::with_store(4, ArtifactStore::new(&dir).unwrap());
        reg2.set_lazy_build(true);
        assert_eq!(reg2.warm_start(&v), 1);
        let (engine, _) = reg2.get_or_compile(&spec, &v, None).unwrap();
        assert!(!engine.is_lazy(), "warm-started engines carry dense tables");
        assert_eq!(reg2.stats().hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hot_overflow_demotes_to_warm_and_promotes_back_without_recompile() {
        let v = vocab();
        let reg = EngineRegistry::with_tiers(1, 4, None);
        let a = ConstraintSpec::builtin("fig3");
        let b = ConstraintSpec::builtin("json");
        let (e1, _) = reg.get_or_compile(&a, &v, None).unwrap();
        reg.get_or_compile(&b, &v, None).unwrap(); // demotes `a` hot→warm
        let s = reg.stats();
        assert_eq!((s.hot_entries, s.warm_entries, s.evictions), (1, 1, 1));
        assert!(reg.contains(&a, &v, None), "warm entries count as resident");
        let (e2, _) = reg.get_or_compile(&a, &v, None).unwrap();
        assert!(Arc::ptr_eq(&e1, &e2), "promotion must reuse the compiled engine");
        let s = reg.stats();
        assert_eq!((s.hits, s.misses), (1, 2), "a warm hit is a hit, not a recompile");
        assert_eq!((s.hot_entries, s.warm_entries), (1, 1), "promotion demoted `b` in turn");
    }

    #[test]
    fn zero_warm_capacity_restores_drop_on_evict() {
        let v = vocab();
        let reg = EngineRegistry::with_tiers(1, 0, None);
        let a = ConstraintSpec::builtin("fig3");
        reg.get_or_compile(&a, &v, None).unwrap();
        reg.get_or_compile(&ConstraintSpec::builtin("json"), &v, None).unwrap();
        assert!(!reg.contains(&a, &v, None), "no warm tier: eviction drops the engine");
        let s = reg.stats();
        assert_eq!((s.entries, s.warm_entries, s.evictions), (1, 0, 1));
    }

    #[test]
    fn warm_start_parks_overflow_in_cold_and_loads_on_demand() {
        let dir = std::env::temp_dir()
            .join(format!("domino_registry_cold_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let v = vocab();
        let a = ConstraintSpec::builtin("fig3");
        let b = ConstraintSpec::builtin("json");
        {
            let reg = EngineRegistry::with_store(4, ArtifactStore::new(&dir).unwrap());
            reg.get_or_compile(&a, &v, None).unwrap();
            reg.get_or_compile(&b, &v, None).unwrap();
        }
        // Hot capacity 1: warm start fully loads one artifact, indexes the
        // other cold.
        let reg2 = EngineRegistry::with_tiers(1, 4, Some(ArtifactStore::new(&dir).unwrap()));
        assert_eq!(reg2.warm_start(&v), 1);
        let s = reg2.stats();
        assert_eq!((s.hot_entries, s.cold_entries), (1, 1));
        // Demanding both specs must never recompile: one is resident, the
        // other is an on-demand artifact load.
        reg2.get_or_compile(&a, &v, None).unwrap();
        reg2.get_or_compile(&b, &v, None).unwrap();
        let s = reg2.stats();
        assert_eq!((s.hits, s.misses), (1, 1), "one resident hit, one cold load");
        assert_eq!((s.artifact_hits, s.artifact_misses), (2, 0), "cold demand hit the store");
        assert_eq!(s.cold_entries, 0, "the cold key became resident");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn warm_start_without_store_is_a_noop() {
        let v = vocab();
        let reg = EngineRegistry::new(4);
        assert_eq!(reg.warm_start(&v), 0);
        assert_eq!(reg.flush_artifacts(), 0);
        assert_eq!(reg.stats().warm_loaded, 0);
    }
}
