//! The shared engine registry: compile each grammar once, serve it
//! everywhere.
//!
//! A compiled [`Engine`] is the expensive artifact of the whole system —
//! scanner union NFA, vocabulary-aligned subterminal trees (Algorithm 2),
//! Earley tables. The paper's premise is that this cost is paid *offline*
//! (§3.5, Table: 1–20 s per grammar); a serving path that rebuilds it per
//! request forfeits the entire headline win. The registry makes the
//! amortization real:
//!
//! * keyed by **content hash** ([`ConstraintSpec::fingerprint`]) × vocab
//!   identity, so a builtin name, an inline EBNF body and a regex all
//!   cache uniformly;
//! * **build-deduplicated**: when N requests race on an uncached grammar,
//!   one thread compiles, the rest block on that build and share the
//!   result (no thundering-herd compile);
//! * **size-bounded** with LRU eviction — an adversarial stream of
//!   distinct inline grammars degrades to recompilation, not unbounded
//!   memory;
//! * each entry carries the engine's shared [`MaskCache`], so state-keyed
//!   mask reuse follows the engine around for free;
//! * counters (hits/misses/evictions/coalesced builds/compile-time) are
//!   exported through `server::metrics` for amortization reporting.

use super::mask_cache::{MaskCache, MaskCacheStats};
use super::ConstraintSpec;
use crate::domino::decoder::Engine;
use crate::tokenizer::Vocab;
use anyhow::bail;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Per-engine mask-cache capacity (distinct `(variant, state)` entries).
const MASK_CACHE_CAPACITY: usize = 4096;

/// Registry counters, readable without blocking builds.
#[derive(Clone, Debug, Default)]
pub struct RegistryStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that triggered a compile.
    pub misses: u64,
    /// Entries dropped by LRU eviction.
    pub evictions: u64,
    /// Lookups that waited on a concurrent build instead of compiling.
    pub coalesced: u64,
    /// Total wall time spent compiling engines, milliseconds.
    pub compile_ms: u64,
    /// Live entries.
    pub entries: usize,
}

struct Entry {
    engine: Arc<Engine>,
    masks: Arc<MaskCache>,
    tick: u64,
}

enum BuildState {
    Pending,
    Ready(Arc<Engine>, Arc<MaskCache>),
    Failed(String),
}

struct Build {
    state: Mutex<BuildState>,
    cv: Condvar,
}

struct Inner {
    map: HashMap<u64, Entry>,
    building: HashMap<u64, Arc<Build>>,
    tick: u64,
    /// Mask-cache counters of evicted/cleared entries, folded in so the
    /// aggregate in [`EngineRegistry::mask_stats`] is monotonic (metrics
    /// consumers compute deltas between snapshots).
    retired_masks: MaskCacheStats,
}

/// A concurrent, content-hash-keyed cache of compiled grammar engines.
pub struct EngineRegistry {
    capacity: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    coalesced: AtomicU64,
    compile_ms: AtomicU64,
}

impl EngineRegistry {
    pub fn new(capacity: usize) -> Arc<EngineRegistry> {
        assert!(capacity >= 1, "registry needs capacity >= 1");
        Arc::new(EngineRegistry {
            capacity,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                building: HashMap::new(),
                tick: 0,
                retired_masks: MaskCacheStats::default(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            compile_ms: AtomicU64::new(0),
        })
    }

    /// The cache key: spec content fingerprint × vocab identity. Vocab
    /// identity is the `Arc` address — sound because every live entry
    /// keeps its vocab alive (the engine holds the `Arc`), so the address
    /// cannot be reused while the entry exists.
    pub fn key_for(spec: &ConstraintSpec, vocab: &Arc<Vocab>) -> u64 {
        spec.fingerprint()
            ^ (Arc::as_ptr(vocab) as usize as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// Fetch the compiled engine for `spec`, compiling it (exactly once,
    /// even under concurrency) on a miss. Returns the engine plus its
    /// shared mask cache.
    pub fn get_or_compile(
        &self,
        spec: &ConstraintSpec,
        vocab: &Arc<Vocab>,
    ) -> crate::Result<(Arc<Engine>, Arc<MaskCache>)> {
        if !spec.is_grammar_backed() {
            bail!("constraint {spec:?} has no grammar engine");
        }
        let key = Self::key_for(spec, vocab);

        let build = {
            let mut inner = self.inner.lock().expect("registry lock");
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.map.get_mut(&key) {
                e.tick = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok((e.engine.clone(), e.masks.clone()));
            }
            if let Some(b) = inner.building.get(&key) {
                // Someone else is compiling this grammar right now: wait
                // for their build instead of duplicating it.
                let b = b.clone();
                drop(inner);
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                let mut st = b.state.lock().expect("build lock");
                loop {
                    match &*st {
                        BuildState::Pending => {}
                        BuildState::Ready(e, m) => return Ok((e.clone(), m.clone())),
                        BuildState::Failed(msg) => bail!("engine compile failed: {msg}"),
                    }
                    st = b.cv.wait(st).expect("build wait");
                }
            }
            let build =
                Arc::new(Build { state: Mutex::new(BuildState::Pending), cv: Condvar::new() });
            inner.building.insert(key, build.clone());
            build
        };

        // Miss: compile outside the registry lock.
        self.misses.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let result = spec.to_cfg().and_then(|cfg| Engine::compile(cfg, vocab.clone()));
        self.compile_ms.fetch_add(t0.elapsed().as_millis() as u64, Ordering::Relaxed);

        match result {
            Ok(engine) => {
                let masks = Arc::new(MaskCache::new(MASK_CACHE_CAPACITY));
                {
                    let mut inner = self.inner.lock().expect("registry lock");
                    inner.building.remove(&key);
                    inner.tick += 1;
                    let tick = inner.tick;
                    if inner.map.len() >= self.capacity {
                        let victim =
                            inner.map.iter().min_by_key(|(_, e)| e.tick).map(|(k, _)| *k);
                        if let Some(old) = victim {
                            if let Some(entry) = inner.map.remove(&old) {
                                let mut s = entry.masks.stats();
                                s.entries = 0; // retired entries are no longer live
                                inner.retired_masks.merge(&s);
                            }
                            self.evictions.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    inner.map.insert(
                        key,
                        Entry { engine: engine.clone(), masks: masks.clone(), tick },
                    );
                }
                let mut st = build.state.lock().expect("build lock");
                *st = BuildState::Ready(engine.clone(), masks.clone());
                drop(st);
                build.cv.notify_all();
                Ok((engine, masks))
            }
            Err(e) => {
                {
                    let mut inner = self.inner.lock().expect("registry lock");
                    inner.building.remove(&key);
                }
                let mut st = build.state.lock().expect("build lock");
                *st = BuildState::Failed(format!("{e:#}"));
                drop(st);
                build.cv.notify_all();
                Err(e)
            }
        }
    }

    /// Is this spec's engine currently cached (no compile triggered)?
    pub fn contains(&self, spec: &ConstraintSpec, vocab: &Arc<Vocab>) -> bool {
        let key = Self::key_for(spec, vocab);
        self.inner.lock().expect("registry lock").map.contains_key(&key)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("registry lock").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached engine (counters are kept; the dropped entries'
    /// mask-cache counters are folded into the retired aggregate).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().expect("registry lock");
        let entries: Vec<Entry> = inner.map.drain().map(|(_, e)| e).collect();
        for e in entries {
            let mut s = e.masks.stats();
            s.entries = 0;
            inner.retired_masks.merge(&s);
        }
    }

    pub fn stats(&self) -> RegistryStats {
        RegistryStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            compile_ms: self.compile_ms.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }

    /// Aggregate mask-cache counters: live entries plus a snapshot of
    /// every evicted/cleared entry's counters at retirement time, so the
    /// totals are monotonic across snapshots. (Hits an in-flight slot
    /// scores on an already-evicted engine's cache after its retirement
    /// snapshot are the one thing not counted.)
    pub fn mask_stats(&self) -> MaskCacheStats {
        let inner = self.inner.lock().expect("registry lock");
        let mut agg = inner.retired_masks.clone();
        for e in inner.map.values() {
            agg.merge(&e.masks.stats());
        }
        agg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer;

    fn vocab() -> Arc<Vocab> {
        Arc::new(tokenizer::bpe::synthetic_json_vocab(256))
    }

    #[test]
    fn hit_after_miss() {
        let v = vocab();
        let reg = EngineRegistry::new(4);
        let spec = ConstraintSpec::builtin("fig3");
        assert!(!reg.contains(&spec, &v));
        let (e1, _) = reg.get_or_compile(&spec, &v).unwrap();
        assert!(reg.contains(&spec, &v));
        let (e2, _) = reg.get_or_compile(&spec, &v).unwrap();
        assert!(Arc::ptr_eq(&e1, &e2), "second lookup must reuse the engine");
        let s = reg.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn normalized_specs_share_an_entry() {
        let v = vocab();
        let reg = EngineRegistry::new(4);
        reg.get_or_compile(&ConstraintSpec::builtin("fig3"), &v).unwrap();
        reg.get_or_compile(&ConstraintSpec::builtin(" FIG3 "), &v).unwrap();
        let s = reg.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn distinct_vocabs_do_not_collide() {
        let v1 = vocab();
        let v2 = Arc::new(tokenizer::bpe::synthetic_json_vocab(320));
        let reg = EngineRegistry::new(4);
        let spec = ConstraintSpec::builtin("fig3");
        let (e1, _) = reg.get_or_compile(&spec, &v1).unwrap();
        let (e2, _) = reg.get_or_compile(&spec, &v2).unwrap();
        assert!(!Arc::ptr_eq(&e1, &e2));
        assert_eq!(reg.stats().misses, 2);
    }

    #[test]
    fn compile_failure_reported_and_not_cached() {
        let v = vocab();
        let reg = EngineRegistry::new(4);
        let bad = ConstraintSpec::builtin("no-such-grammar");
        assert!(reg.get_or_compile(&bad, &v).is_err());
        assert!(!reg.contains(&bad, &v));
        // A failed build must not wedge later lookups of the same key.
        assert!(reg.get_or_compile(&bad, &v).is_err());
    }
}
