//! Stop-sequence constraints: free generation until a byte sequence
//! appears in the output, then EOS is forced.
//!
//! This is the workhorse constraint of production serving APIs ("stop":
//! ["\n\n", "```"]) and needs none of the grammar machinery: the checker
//! keeps a rolling tail of emitted bytes (long enough to catch sequences
//! straddling token boundaries) and flips to EOS-only once any sequence
//! matches. The completed stop text is *included* in the output — the
//! standard API semantics.

use crate::domino::{Checker, TokenMask};
use crate::tokenizer::{Vocab, EOS_ID};
use crate::TokenId;
use anyhow::bail;
use std::sync::Arc;

/// A [`Checker`] enforcing stop sequences over the output byte stream.
pub struct StopChecker {
    vocab: Arc<Vocab>,
    sequences: Vec<Vec<u8>>,
    /// Rolling tail of emitted bytes (longest sequence − 1, plus the
    /// bytes of the token being fed).
    tail: Vec<u8>,
    hit: bool,
    keep: usize,
    /// The only two masks this checker ever produces, prebuilt so
    /// `compute_mask` is an `Arc` clone per step.
    mask_all: Arc<TokenMask>,
    mask_eos: Arc<TokenMask>,
}

impl StopChecker {
    /// Empty sequences are dropped; with no (non-empty) sequences this
    /// degenerates to an unconstrained checker.
    pub fn new(vocab: Arc<Vocab>, sequences: &[String]) -> StopChecker {
        let sequences: Vec<Vec<u8>> =
            sequences.iter().filter(|s| !s.is_empty()).map(|s| s.as_bytes().to_vec()).collect();
        let keep = sequences.iter().map(|s| s.len()).max().unwrap_or(1).saturating_sub(1);
        let mask_all = Arc::new(TokenMask::all(vocab.len()));
        let mask_eos = {
            let mut m = TokenMask::none(vocab.len());
            m.allow(EOS_ID);
            Arc::new(m)
        };
        StopChecker { vocab, sequences, tail: Vec::new(), hit: false, keep, mask_all, mask_eos }
    }

    /// Has a stop sequence been completed?
    pub fn hit(&self) -> bool {
        self.hit
    }

    fn feed(&mut self, bytes: &[u8]) {
        if self.hit || bytes.is_empty() {
            return;
        }
        self.tail.extend_from_slice(bytes);
        if self.sequences.iter().any(|s| self.tail.windows(s.len()).any(|w| w == &s[..])) {
            self.hit = true;
            return;
        }
        if self.tail.len() > self.keep {
            let cut = self.tail.len() - self.keep;
            self.tail.drain(..cut);
        }
    }
}

impl Checker for StopChecker {
    fn advance(&mut self, token: TokenId) -> crate::Result<()> {
        if self.hit {
            bail!("generation already hit a stop sequence; only EOS is legal");
        }
        let bytes = self.vocab.token_bytes(token).to_vec();
        self.feed(&bytes);
        Ok(())
    }

    fn compute_mask(&mut self) -> Arc<TokenMask> {
        if self.hit {
            self.mask_eos.clone()
        } else {
            self.mask_all.clone()
        }
    }

    fn check_token(&mut self, token: TokenId) -> bool {
        !self.hit || token == EOS_ID
    }

    fn reset(&mut self) {
        self.tail.clear();
        self.hit = false;
    }

    fn check_bytes(&mut self, _bytes: &[u8]) -> bool {
        true
    }

    fn advance_bytes(&mut self, bytes: &[u8]) -> crate::Result<()> {
        self.feed(bytes);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::{self, NUM_SPECIAL};

    fn byte_tok(b: u8) -> TokenId {
        (b as usize + NUM_SPECIAL) as TokenId
    }

    fn checker(sequences: &[&str]) -> StopChecker {
        let seqs: Vec<String> = sequences.iter().map(|s| s.to_string()).collect();
        StopChecker::new(Arc::new(tokenizer::Vocab::byte_level()), &seqs)
    }

    #[test]
    fn stops_on_sequence_across_token_boundaries() {
        let mut c = checker(&["END"]);
        for b in b"some text EN" {
            assert!(c.check_token(byte_tok(*b)));
            c.advance(byte_tok(*b)).unwrap();
        }
        assert!(!c.hit(), "EN alone is not END");
        c.advance(byte_tok(b'D')).unwrap();
        assert!(c.hit());
        // Only EOS is legal now; mask agrees with check_token.
        assert!(c.check_token(EOS_ID));
        assert!(!c.check_token(byte_tok(b'x')));
        let m = c.compute_mask();
        assert_eq!(m.count(), 1);
        assert!(m.allowed(EOS_ID));
        assert!(c.advance(byte_tok(b'x')).is_err());
    }

    #[test]
    fn multiple_sequences_any_triggers() {
        let mut c = checker(&["\n\n", "}"]);
        for b in b"{\"a\": 1}" {
            c.advance(byte_tok(*b)).unwrap();
        }
        assert!(c.hit());
    }

    #[test]
    fn healing_bytes_count_toward_stop() {
        let mut c = checker(&["ab"]);
        assert!(c.check_bytes(b"whatever"));
        c.advance_bytes(b"xa").unwrap();
        assert!(!c.hit());
        c.advance_bytes(b"b").unwrap();
        assert!(c.hit());
    }

    #[test]
    fn reset_and_degenerate_cases() {
        let mut c = checker(&["X"]);
        c.advance(byte_tok(b'X')).unwrap();
        assert!(c.hit());
        c.reset();
        assert!(!c.hit());
        assert_eq!(c.compute_mask().count(), c.vocab.len());

        // No sequences → never stops.
        let mut c = checker(&[]);
        for b in b"anything at all" {
            c.advance(byte_tok(*b)).unwrap();
        }
        assert!(!c.hit());

        // Empty strings are dropped, not instant-stops.
        let mut c = checker(&["", "Z"]);
        c.advance(byte_tok(b'a')).unwrap();
        assert!(!c.hit());
        c.advance(byte_tok(b'Z')).unwrap();
        assert!(c.hit());
    }
}
