//! Persistent precompute artifacts: compiled engines that survive the
//! process.
//!
//! DOMINO's speed comes from per-grammar precomputation (§3.5: scanner
//! DFAs, vocabulary-aligned subterminal trees, 1–20 s per grammar), but
//! an in-memory [`EngineRegistry`](super::EngineRegistry) loses that work
//! on every restart — a fleet pays the cold-start tax per deploy. This
//! module snapshots a compiled [`Engine`] (plus the hot entries of its
//! [`MaskCache`](super::MaskCache)) to a versioned, checksummed binary
//! file so a restarted process serves its first constrained request with
//! zero compile latency.
//!
//! ## File layout (`<artifact-dir>/<key:016x>.domino`)
//!
//! ```text
//! magic    b"DOMA"
//! version  u32    — ARTIFACT_VERSION; any mismatch = rebuild
//! checksum u64    — FNV-1a 64 over every byte after this field
//! key      u64    — ConstraintSpec::build_fingerprint(vocab_fp, k)
//! vocab_fp u64    — Vocab::fingerprint() of the build vocabulary
//! vocab_len u64
//! label    str    — human tag ("builtin:json"), diagnostics only
//! payload_len u64
//! payload         — grammar, scanner DFAs, subterminal trees, hot masks
//! ```
//!
//! ## Invalidation rules
//!
//! An artifact is used only if **all** of these hold; otherwise the load
//! reports [`ArtifactLoad::Invalid`] and the caller rebuilds from source
//! (never errors out, never serves a stale engine):
//!
//! * magic + version match this build,
//! * the checksum verifies over the complete remainder of the file (so a
//!   truncated or bit-flipped file — header fields included — is caught
//!   before any field is trusted),
//! * the vocab fingerprint and length match the live vocabulary,
//! * the header key matches the requested build fingerprint,
//! * every index decoded from the payload is in range.
//!
//! ## Atomic write-back
//!
//! [`ArtifactStore::save`] writes to a `.tmp-<pid>-<seq>` sibling, syncs,
//! then renames over the final name — rename is atomic within a
//! directory, so concurrent readers (and crashed writers) only ever see
//! complete files. The warm-start scan skips non-`.domino` files.

use super::ConstraintSpec;
use crate::domino::decoder::Engine;
use crate::domino::SpeculativeModel;
use crate::domino::tree::{PosSets, Tree, TreeNode, TreeSet};
use crate::domino::TokenMask;
use crate::grammar::{Cfg, Production, Symbol, Terminal, TerminalKind};
use crate::regex::dfa::{Dfa, DEAD};
use crate::scanner::{Pos, Scanner};
use crate::tokenizer::Vocab;
use crate::util::binio::{fnv1a_64, ByteReader, ByteWriter};
use anyhow::{bail, Context};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Bump on any change to the header or payload layout.
pub const ARTIFACT_VERSION: u32 = 1;

const MAGIC: &[u8; 4] = b"DOMA";

/// Speculation-prior records (`<key:016x>.prior`) are persisted separately
/// from engine artifacts — priors mutate with traffic, engines don't, and
/// re-snapshotting an engine to update its prior would be absurd. Layout:
/// magic `b"DOMP"`, version, FNV-1a checksum over the rest, key, then the
/// [`SpeculativeModel`] encoding (unigram + n-gram continuation counts —
/// see `SpeculativeModel::to_bytes`). Bump on any change to that record
/// or the model encoding it wraps.
pub const PRIOR_VERSION: u32 = 1;

const PRIOR_MAGIC: &[u8; 4] = b"DOMP";

/// One persisted mask-cache entry (see
/// [`MaskCache::hot_entries`](super::MaskCache::hot_entries)).
///
/// The mask is held behind an `Arc` — the same sharing unit the cache
/// stores — so seeding a warm registry never deep-copies bitsets.
#[derive(Clone, Debug)]
pub struct MaskSeed {
    pub variant: u64,
    pub state: u64,
    pub mask: Arc<TokenMask>,
}

/// Outcome of a targeted artifact lookup.
pub enum ArtifactLoad {
    /// Deserialized and fully validated.
    Hit { engine: Arc<Engine>, masks: Vec<MaskSeed>, label: String },
    /// No artifact on disk for this key.
    Miss,
    /// An artifact exists but is unusable (truncated, corrupt, version or
    /// vocab mismatch). The caller must rebuild and overwrite.
    Invalid { reason: String },
}

/// One artifact recovered by the warm-start scan.
pub struct LoadedArtifact {
    pub key: u64,
    pub label: String,
    pub engine: Arc<Engine>,
    pub masks: Vec<MaskSeed>,
}

/// An on-disk directory of engine artifacts, keyed by build fingerprint.
pub struct ArtifactStore {
    dir: PathBuf,
}

/// Uniquifies temp names across threads within one process.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

impl ArtifactStore {
    pub fn new(dir: impl Into<PathBuf>) -> crate::Result<ArtifactStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating artifact dir {}", dir.display()))?;
        Ok(ArtifactStore { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The artifact path for a build fingerprint.
    pub fn path_for(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.domino"))
    }

    /// Persist a compiled engine (and optionally its warm masks) under
    /// the spec's build fingerprint. Atomic: write temp + rename.
    pub fn save(
        &self,
        spec: &ConstraintSpec,
        vocab: &Arc<Vocab>,
        k: Option<u32>,
        engine: &Engine,
        masks: &[MaskSeed],
    ) -> crate::Result<PathBuf> {
        let key = spec.build_fingerprint(vocab.fingerprint(), k);
        self.save_keyed(key, &spec.label(), engine, masks)
    }

    /// [`Self::save`] for callers that already hold the key (re-saves of
    /// registry entries, whose original spec is no longer around).
    ///
    /// Lazily-compiled engines are materialized first
    /// ([`Engine::materialize_full`]): the artifact always carries dense
    /// tables, with the lazy engine's discovered state numbering preserved
    /// so the persisted mask seeds stay valid.
    pub fn save_keyed(
        &self,
        key: u64,
        label: &str,
        engine: &Engine,
        masks: &[MaskSeed],
    ) -> crate::Result<PathBuf> {
        let materialized;
        let engine = if engine.is_lazy() {
            materialized = engine.materialize_full();
            &*materialized
        } else {
            engine
        };
        let data = encode_artifact(key, label, engine, masks);
        self.publish(key, self.path_for(key), &data)
    }

    /// Write `data` to a temp sibling, sync, and rename over `path`
    /// (atomic within the directory — readers and crashed writers only
    /// ever see complete files).
    fn publish(&self, key: u64, path: PathBuf, data: &[u8]) -> crate::Result<PathBuf> {
        let tmp = self.dir.join(format!(
            "{key:016x}.tmp-{}-{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let write = (|| -> std::io::Result<()> {
            use std::io::Write as _;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(data)?;
            f.sync_all()
        })();
        if let Err(e) = write {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("writing artifact {}", tmp.display()));
        }
        if let Err(e) = std::fs::rename(&tmp, &path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("publishing artifact {}", path.display()));
        }
        Ok(path)
    }

    /// The prior-record path for a build fingerprint.
    pub fn prior_path_for(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.prior"))
    }

    /// Persist a speculation prior under its build fingerprint (versioned
    /// + checksummed + atomic, like engine artifacts; see
    /// [`PRIOR_VERSION`]). Flushed by engine shards on clean shutdown so a
    /// restarted server drafts from warm priors.
    pub fn save_prior(&self, key: u64, model: &SpeculativeModel) -> crate::Result<PathBuf> {
        let mut body = ByteWriter::new();
        body.u64(key);
        body.raw(&model.to_bytes());
        let body = body.into_inner();
        let mut w = ByteWriter::new();
        w.raw(PRIOR_MAGIC);
        w.u32(PRIOR_VERSION);
        w.u64(fnv1a_64(&body));
        w.raw(&body);
        self.publish(key, self.prior_path_for(key), &w.into_inner())
    }

    /// Load the persisted speculation prior for a build fingerprint.
    /// `None` for missing, corrupt, mis-keyed or version-skewed records —
    /// the caller starts from a cold prior instead (priors are a
    /// performance aid, never correctness, so there is no `Invalid`
    /// diagnosis to act on).
    pub fn load_prior(&self, key: u64) -> Option<SpeculativeModel> {
        let data = std::fs::read(self.prior_path_for(key)).ok()?;
        let mut r = ByteReader::new(&data);
        if r.raw(4).ok()? != PRIOR_MAGIC || r.u32().ok()? != PRIOR_VERSION {
            return None;
        }
        let checksum = r.u64().ok()?;
        let body = r.rest();
        if fnv1a_64(body) != checksum {
            return None;
        }
        let mut r = ByteReader::new(body);
        if r.u64().ok()? != key {
            return None;
        }
        SpeculativeModel::from_bytes(r.rest()).ok()
    }

    /// Look up the artifact for `(spec, vocab, k)`.
    pub fn load(&self, spec: &ConstraintSpec, vocab: &Arc<Vocab>, k: Option<u32>) -> ArtifactLoad {
        self.load_keyed(spec.build_fingerprint(vocab.fingerprint(), k), vocab)
    }

    /// Look up an artifact by its build fingerprint.
    pub fn load_keyed(&self, key: u64, vocab: &Arc<Vocab>) -> ArtifactLoad {
        let path = self.path_for(key);
        let data = match std::fs::read(&path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return ArtifactLoad::Miss,
            Err(e) => {
                return ArtifactLoad::Invalid { reason: format!("reading {}: {e}", path.display()) }
            }
            Ok(d) => d,
        };
        match decode_artifact(&data, key, vocab) {
            Ok((engine, masks, label)) => ArtifactLoad::Hit { engine, masks, label },
            Err(e) => ArtifactLoad::Invalid { reason: format!("{e:#}") },
        }
    }

    /// Load up to `limit` artifacts that validate against `vocab` — the
    /// warm-start scan. Artifacts for other vocabularies are skipped
    /// cheaply after the header check (a shared store may serve several
    /// models); unusable files are counted in the second return value.
    /// The limit keeps a large shared store from deserializing engines a
    /// capacity-bounded registry would immediately discard.
    pub fn scan(&self, vocab: &Arc<Vocab>, limit: usize) -> (Vec<LoadedArtifact>, usize) {
        let mut out = Vec::new();
        let mut invalid = 0usize;
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return (out, invalid) };
        for entry in entries.flatten() {
            if out.len() >= limit {
                break;
            }
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("domino") {
                continue; // temp files and foreign files are not artifacts
            }
            let Ok(data) = std::fs::read(&path) else {
                invalid += 1;
                continue;
            };
            // read_header checksum-verifies everything, so the payload
            // can be decoded directly — no second parse of the file.
            let Ok((header, payload)) = read_header(&data) else {
                invalid += 1;
                continue;
            };
            if header.vocab_fp != vocab.fingerprint() || header.vocab_len != vocab.len() as u64 {
                continue; // another model's artifact — not ours to judge
            }
            match decode_payload(payload, vocab) {
                Ok((engine, masks)) => {
                    out.push(LoadedArtifact { key: header.key, label: header.label, engine, masks })
                }
                Err(_) => invalid += 1,
            }
        }
        (out, invalid)
    }

    /// Index every artifact valid-looking for `vocab` by reading only the
    /// fixed [`INDEX_PREFIX_LEN`]-byte envelope prefix per file — O(index)
    /// in file count, never O(corpus) in payload bytes, so a 100k-grammar
    /// store is scannable at boot in milliseconds. The checksum covers the
    /// whole body and is therefore **not** verified here; a file whose
    /// prefix lies (truncation or corruption past byte 40) is indexed but
    /// rejected by [`Self::load_keyed`] on first demand, which falls back
    /// to a clean rebuild exactly like any other invalid artifact. The
    /// second return value counts files whose prefix itself is unreadable.
    pub fn scan_index(&self, vocab: &Arc<Vocab>) -> (Vec<ArtifactHeader>, usize) {
        let mut out = Vec::new();
        let mut invalid = 0usize;
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return (out, invalid) };
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("domino") {
                continue;
            }
            match read_index_prefix(&path) {
                Ok(Some(h)) if h.vocab_fp == vocab.fingerprint()
                    && h.vocab_len == vocab.len() as u64 =>
                {
                    out.push(h)
                }
                Ok(_) => {} // another model's artifact — not ours to judge
                Err(_) => invalid += 1,
            }
        }
        (out, invalid)
    }

    /// Populate the store with `count` synthetic artifacts cloned from one
    /// compiled engine — the registry-at-scale stress corpus. The payload
    /// is encoded **once**; each file re-wraps it under a distinct
    /// synthetic key (`fnv1a("domino-synthetic-{i}")`) with its own valid
    /// checksum, so every file parses, indexes, and loads like a real
    /// artifact while generation stays I/O-bound. Synthetic keys are not
    /// build fingerprints of any real spec, so normal traffic never
    /// resolves to them. Returns the keys written, in write order.
    pub fn seed_synthetic_corpus(
        &self,
        engine: &Engine,
        count: usize,
    ) -> crate::Result<Vec<u64>> {
        let payload = encode_payload(engine, &[]);
        let vocab_fp = engine.vocab.fingerprint();
        let vocab_len = engine.vocab.len() as u64;
        let mut keys = Vec::with_capacity(count);
        for i in 0..count {
            let key = fnv1a_64(format!("domino-synthetic-{i}").as_bytes());
            let mut body = ByteWriter::new();
            body.u64(key);
            body.u64(vocab_fp);
            body.u64(vocab_len);
            body.str(&format!("synthetic:{i}"));
            body.u64(payload.len() as u64);
            body.raw(&payload);
            let body = body.into_inner();
            let mut w = ByteWriter::new();
            w.raw(MAGIC);
            w.u32(ARTIFACT_VERSION);
            w.u64(fnv1a_64(&body));
            w.raw(&body);
            self.publish(key, self.path_for(key), &w.into_inner())?;
            keys.push(key);
        }
        Ok(keys)
    }
}

/// Bytes of envelope prefix read per file by [`ArtifactStore::scan_index`]:
/// magic(4) + version(4) + checksum(8) + key(8) + vocab_fp(8) + vocab_len(8).
pub const INDEX_PREFIX_LEN: usize = 40;

/// The fixed-size slice of an artifact header recoverable from the first
/// [`INDEX_PREFIX_LEN`] bytes alone (the label that follows is
/// variable-length and irrelevant to admission — it rides in on the full
/// load).
#[derive(Clone, Copy, Debug)]
pub struct ArtifactHeader {
    pub key: u64,
    pub vocab_fp: u64,
    pub vocab_len: u64,
}

/// Read and parse the fixed index prefix of one artifact file. `Ok(None)`
/// means the file is well-formed but not from this build (magic/version);
/// `Err` means the prefix itself is unreadable or truncated.
fn read_index_prefix(path: &Path) -> crate::Result<Option<ArtifactHeader>> {
    use std::io::Read as _;
    let mut buf = [0u8; INDEX_PREFIX_LEN];
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening artifact {}", path.display()))?;
    f.read_exact(&mut buf)
        .with_context(|| format!("artifact {} shorter than its header", path.display()))?;
    let mut r = ByteReader::new(&buf);
    if r.raw(4)? != MAGIC {
        return Ok(None);
    }
    if r.u32()? != ARTIFACT_VERSION {
        return Ok(None);
    }
    let _checksum = r.u64()?; // verified over the whole body at load time
    Ok(Some(ArtifactHeader { key: r.u64()?, vocab_fp: r.u64()?, vocab_len: r.u64()? }))
}

struct Header {
    key: u64,
    vocab_fp: u64,
    vocab_len: u64,
    label: String,
}

/// Parse + integrity-check the envelope; returns the header and the
/// payload slice. After this returns `Ok`, every header field and payload
/// byte is checksum-verified.
fn read_header(data: &[u8]) -> crate::Result<(Header, &[u8])> {
    let mut r = ByteReader::new(data);
    if r.raw(4)? != MAGIC {
        bail!("not a domino artifact (bad magic)");
    }
    let version = r.u32()?;
    if version != ARTIFACT_VERSION {
        bail!("artifact version {version}; this build reads {ARTIFACT_VERSION}");
    }
    let checksum = r.u64()?;
    let body = r.rest();
    if fnv1a_64(body) != checksum {
        bail!("checksum mismatch (truncated or corrupt artifact)");
    }
    let mut r = ByteReader::new(body);
    let key = r.u64()?;
    let vocab_fp = r.u64()?;
    let vocab_len = r.u64()?;
    let label = r.str()?;
    let payload_len = r.u64()?;
    let payload = r.rest();
    if payload.len() as u64 != payload_len {
        bail!("payload length field disagrees: {} of {} bytes", payload.len(), payload_len);
    }
    Ok((Header { key, vocab_fp, vocab_len, label }, payload))
}

fn encode_artifact(key: u64, label: &str, engine: &Engine, masks: &[MaskSeed]) -> Vec<u8> {
    let payload = encode_payload(engine, masks);
    let mut body = ByteWriter::new();
    body.u64(key);
    body.u64(engine.vocab.fingerprint());
    body.u64(engine.vocab.len() as u64);
    body.str(label);
    body.u64(payload.len() as u64);
    body.raw(&payload);
    let body = body.into_inner();
    let mut w = ByteWriter::new();
    w.raw(MAGIC);
    w.u32(ARTIFACT_VERSION);
    w.u64(fnv1a_64(&body));
    w.raw(&body);
    w.into_inner()
}

/// Targeted decode: header + vocab + expected-key validation, then the
/// payload. (The warm-start scan validates the header itself and calls
/// [`decode_payload`] directly.)
fn decode_artifact(
    data: &[u8],
    expect_key: u64,
    vocab: &Arc<Vocab>,
) -> crate::Result<(Arc<Engine>, Vec<MaskSeed>, String)> {
    let (h, payload) = read_header(data)?;
    // Vocab identity first: "built for another vocabulary" is the right
    // diagnosis even when the key also disagrees (renamed/copied files).
    if h.vocab_fp != vocab.fingerprint() || h.vocab_len != vocab.len() as u64 {
        bail!(
            "vocab fingerprint mismatch: artifact {:016x}/{} vs live {:016x}/{}",
            h.vocab_fp,
            h.vocab_len,
            vocab.fingerprint(),
            vocab.len()
        );
    }
    if h.key != expect_key {
        bail!("artifact key {:016x} does not match expected {expect_key:016x}", h.key);
    }
    let (engine, masks) = decode_payload(payload, vocab)?;
    Ok((engine, masks, h.label))
}

fn encode_payload(engine: &Engine, masks: &[MaskSeed]) -> Vec<u8> {
    let mut w = ByteWriter::new();
    // --- grammar ---
    let g = &engine.grammar;
    w.u32(g.terminals.len() as u32);
    for t in &g.terminals {
        w.str(&t.name);
        match &t.kind {
            TerminalKind::Literal(b) => {
                w.u8(0);
                w.bytes(b);
            }
            TerminalKind::Regex(p) => {
                w.u8(1);
                w.str(p);
            }
        }
    }
    w.u32(g.nonterminals.len() as u32);
    for n in &g.nonterminals {
        w.str(n);
    }
    w.u32(g.productions.len() as u32);
    for p in &g.productions {
        w.u32(p.lhs);
        w.u32(p.rhs.len() as u32);
        for s in &p.rhs {
            match s {
                Symbol::T(t) => {
                    w.u8(0);
                    w.u32(*t);
                }
                Symbol::Nt(n) => {
                    w.u8(1);
                    w.u32(*n);
                }
            }
        }
    }
    w.u32(g.start);
    // --- scanner DFAs ---
    let dfas = engine.scanner.dense_dfas().expect("save path materializes lazy engines");
    w.u32(dfas.len() as u32);
    for d in dfas {
        w.u32(d.start);
        w.u32(d.num_states() as u32);
        for &a in &d.accepting {
            w.u8(a as u8);
        }
        for &t in &d.trans {
            w.u32(t);
        }
    }
    // --- subterminal trees ---
    let ts = &engine.trees;
    let (trees, possets) = ts.complete_parts();
    w.u64(ts.vocab_size() as u64);
    w.u32(possets.len() as u32);
    for i in 0..possets.len() {
        let info = possets.get(i as u32);
        w.u32(info.positions.len() as u32);
        for &p in &info.positions {
            match p {
                Pos::Boundary => w.u8(0),
                Pos::In(t, s) => {
                    w.u8(1);
                    w.u32(t);
                    w.u32(s);
                }
            }
        }
    }
    w.u32(trees.len() as u32);
    for tree in trees {
        w.u32(tree.nodes.len() as u32);
        for node in &tree.nodes {
            w.u32(node.children.len() as u32);
            for &(term, child) in &node.children {
                w.u32(term);
                w.u32(child);
            }
            w.u32(node.entries.len() as u32);
            for (set_id, tokens) in &node.entries {
                w.u32(*set_id);
                w.u32(tokens.len() as u32);
                for &t in tokens {
                    w.u32(t);
                }
            }
        }
    }
    // --- hot masks ---
    w.u32(masks.len() as u32);
    for m in masks {
        w.u64(m.variant);
        w.u64(m.state);
        w.u64(m.mask.size() as u64);
        let words = m.mask.words();
        w.u32(words.len() as u32);
        for &word in words {
            w.u64(word);
        }
    }
    w.into_inner()
}

fn decode_payload(
    payload: &[u8],
    vocab: &Arc<Vocab>,
) -> crate::Result<(Arc<Engine>, Vec<MaskSeed>)> {
    let mut r = ByteReader::new(payload);
    // --- grammar ---
    let nterm = r.u32()? as usize;
    let mut terminals = Vec::new();
    for _ in 0..nterm {
        let name = r.str()?;
        let kind = match r.u8()? {
            0 => TerminalKind::Literal(r.bytes()?.to_vec()),
            1 => TerminalKind::Regex(r.str()?),
            t => bail!("unknown terminal kind tag {t}"),
        };
        terminals.push(Terminal { name, kind });
    }
    let nnt = r.u32()? as usize;
    let mut nonterminals = Vec::new();
    for _ in 0..nnt {
        nonterminals.push(r.str()?);
    }
    let nprod = r.u32()? as usize;
    let mut productions = Vec::new();
    for _ in 0..nprod {
        let lhs = r.u32()?;
        let nrhs = r.u32()? as usize;
        let mut rhs = Vec::new();
        for _ in 0..nrhs {
            rhs.push(match r.u8()? {
                0 => Symbol::T(r.u32()?),
                1 => Symbol::Nt(r.u32()?),
                t => bail!("unknown symbol tag {t}"),
            });
        }
        productions.push(Production { lhs, rhs });
    }
    let start = r.u32()?;
    // Cfg::new re-validates all ids and recomputes the derived tables.
    let cfg = Cfg::new(terminals, nonterminals, productions, start)
        .context("artifact grammar failed validation")?;
    // --- scanner DFAs ---
    let ndfa = r.u32()? as usize;
    if ndfa != cfg.num_terminals() {
        bail!("artifact has {ndfa} DFAs for {} terminals", cfg.num_terminals());
    }
    let mut dfas = Vec::new();
    for _ in 0..ndfa {
        let dfa_start = r.u32()?;
        let n = r.u32()? as usize;
        if n == 0 {
            bail!("DFA with zero states");
        }
        if dfa_start as usize >= n {
            bail!("DFA start state out of range");
        }
        let mut accepting = Vec::new();
        for _ in 0..n {
            accepting.push(match r.u8()? {
                0 => false,
                1 => true,
                t => bail!("bad accepting flag {t}"),
            });
        }
        let mut trans = Vec::new();
        for _ in 0..n * 256 {
            let t = r.u32()?;
            if t != DEAD && t as usize >= n {
                bail!("DFA transition out of range");
            }
            trans.push(t);
        }
        dfas.push(Dfa { trans, accepting, start: dfa_start });
    }
    let scanner = Scanner::from_dfas(dfas);
    // --- subterminal trees ---
    let vocab_size = r.u64()? as usize;
    if vocab_size != vocab.len() {
        bail!("artifact trees built for vocab of {vocab_size}, live vocab has {}", vocab.len());
    }
    let nsets = r.u32()? as usize;
    let mut sets = Vec::new();
    for _ in 0..nsets {
        let np = r.u32()? as usize;
        let mut set = Vec::new();
        for _ in 0..np {
            set.push(match r.u8()? {
                0 => Pos::Boundary,
                1 => {
                    let t = r.u32()?;
                    let s = r.u32()?;
                    let states = if (t as usize) < scanner.num_terminals() {
                        scanner.num_states_of(t as usize)
                    } else {
                        0
                    };
                    if s as usize >= states {
                        bail!("posset position out of range");
                    }
                    Pos::In(t, s)
                }
                t => bail!("unknown position tag {t}"),
            });
        }
        sets.push(set);
    }
    let possets = PosSets::from_positions(&scanner, sets)?;
    let ntrees = r.u32()? as usize;
    if ntrees != scanner.num_pos() {
        bail!("artifact has {ntrees} trees for {} scanner positions", scanner.num_pos());
    }
    let mut trees = Vec::new();
    for _ in 0..ntrees {
        let nnodes = r.u32()? as usize;
        if nnodes == 0 {
            bail!("tree without a root node");
        }
        let mut nodes = Vec::new();
        for _ in 0..nnodes {
            let nchildren = r.u32()? as usize;
            let mut children = Vec::new();
            for _ in 0..nchildren {
                let term = r.u32()?;
                let child = r.u32()?;
                if term as usize >= cfg.num_terminals() || child as usize >= nnodes {
                    bail!("tree edge out of range");
                }
                children.push((term, child));
            }
            let nentries = r.u32()? as usize;
            let mut entries = Vec::new();
            for _ in 0..nentries {
                let set_id = r.u32()?;
                if set_id as usize >= possets.len() {
                    bail!("tree entry references unknown posset");
                }
                let ntok = r.u32()? as usize;
                let mut tokens = Vec::new();
                for _ in 0..ntok {
                    let t = r.u32()?;
                    if t as usize >= vocab.len() {
                        bail!("tree entry token out of vocab range");
                    }
                    tokens.push(t);
                }
                entries.push((set_id, tokens));
            }
            nodes.push(TreeNode { children, entries });
        }
        trees.push(Tree { nodes });
    }
    let trees = TreeSet::from_parts(trees, possets, vocab_size);
    // --- hot masks ---
    let nmasks = r.u32()? as usize;
    let mut masks = Vec::new();
    for _ in 0..nmasks {
        let variant = r.u64()?;
        let state = r.u64()?;
        let size = r.u64()? as usize;
        if size != vocab.len() {
            bail!("cached mask sized {size} for vocab {}", vocab.len());
        }
        let nwords = r.u32()? as usize;
        let mut words = Vec::new();
        for _ in 0..nwords {
            words.push(r.u64()?);
        }
        masks.push(MaskSeed { variant, state, mask: Arc::new(TokenMask::from_words(size, words)?) });
    }
    r.expect_end()?;
    Ok((Engine::from_parts(cfg, scanner, trees, vocab.clone()), masks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domino::decoder::{DominoDecoder, Lookahead};
    use crate::domino::Checker;
    use crate::tokenizer;

    fn temp_store(tag: &str) -> ArtifactStore {
        let dir = std::env::temp_dir()
            .join(format!("domino_artifact_unit_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        ArtifactStore::new(dir).unwrap()
    }

    fn vocab() -> Arc<Vocab> {
        Arc::new(tokenizer::bpe::synthetic_json_vocab(256))
    }

    #[test]
    fn save_load_roundtrip_produces_identical_masks() {
        let store = temp_store("roundtrip");
        let v = vocab();
        let spec = ConstraintSpec::builtin("fig3");
        let engine =
            Engine::compile(spec.to_cfg().unwrap(), v.clone()).unwrap();
        let seed = MaskSeed { variant: 7, state: 42, mask: Arc::new(TokenMask::all(v.len())) };
        let path = store.save(&spec, &v, None, &engine, &[seed]).unwrap();
        assert!(path.exists());
        let ArtifactLoad::Hit { engine: loaded, masks, label } = store.load(&spec, &v, None)
        else {
            panic!("expected a hit");
        };
        assert_eq!(label, "builtin:fig3");
        assert_eq!(masks.len(), 1);
        assert_eq!((masks[0].variant, masks[0].state), (7, 42));
        assert_eq!(*masks[0].mask, TokenMask::all(v.len()));
        // The loaded engine masks exactly like the fresh one, across a walk.
        let mut a = DominoDecoder::new(engine, Lookahead::Infinite);
        let mut b = DominoDecoder::new(loaded, Lookahead::Infinite);
        for &id in &v.encode(b"(12+3)") {
            assert_eq!(a.compute_mask(), b.compute_mask());
            a.advance(id).unwrap();
            b.advance(id).unwrap();
        }
        assert_eq!(a.compute_mask(), b.compute_mask());
    }

    #[test]
    fn lazy_engine_is_materialized_on_save() {
        // Saving a lazily-compiled engine snapshots dense tables; the
        // reloaded engine is eager and masks identically.
        let store = temp_store("lazy");
        let v = vocab();
        let spec = ConstraintSpec::builtin("json");
        let engine = Engine::compile_lazy(spec.to_cfg().unwrap(), v.clone()).unwrap();
        assert!(engine.is_lazy());
        // Partially explore before saving — numbering must survive.
        let mut d = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        for &id in &v.encode(b"{\"a\": 1") {
            d.compute_mask();
            d.advance(id).unwrap();
        }
        let path = store.save(&spec, &v, None, &engine, &[]).unwrap();
        assert!(path.exists());
        let ArtifactLoad::Hit { engine: loaded, .. } = store.load(&spec, &v, None) else {
            panic!("expected a hit");
        };
        assert!(!loaded.is_lazy(), "artifacts always carry dense tables");
        let mut a = DominoDecoder::new(engine, Lookahead::Infinite);
        let mut b = DominoDecoder::new(loaded, Lookahead::Infinite);
        for &id in &v.encode(b"{\"name\": \"Jo\", \"age\": 3}") {
            assert_eq!(a.compute_mask(), b.compute_mask());
            a.advance(id).unwrap();
            b.advance(id).unwrap();
        }
        assert_eq!(a.compute_mask(), b.compute_mask());
    }

    #[test]
    fn missing_and_key_scoped_lookups() {
        let store = temp_store("miss");
        let v = vocab();
        let spec = ConstraintSpec::builtin("fig3");
        assert!(matches!(store.load(&spec, &v, None), ArtifactLoad::Miss));
        let engine = Engine::compile(spec.to_cfg().unwrap(), v.clone()).unwrap();
        store.save(&spec, &v, Some(2), &engine, &[]).unwrap();
        // Saved under k=2 only: k=None and k=3 are distinct builds.
        assert!(matches!(store.load(&spec, &v, Some(2)), ArtifactLoad::Hit { .. }));
        assert!(matches!(store.load(&spec, &v, None), ArtifactLoad::Miss));
        assert!(matches!(store.load(&spec, &v, Some(3)), ArtifactLoad::Miss));
    }

    #[test]
    fn every_corrupted_byte_is_detected() {
        let store = temp_store("corrupt");
        let v = vocab();
        let spec = ConstraintSpec::builtin("fig3");
        let engine = Engine::compile(spec.to_cfg().unwrap(), v.clone()).unwrap();
        let path = store.save(&spec, &v, None, &engine, &[]).unwrap();
        let good = std::fs::read(&path).unwrap();
        // Flip one byte at a spread of offsets (header and payload): the
        // load must never panic and never report a hit.
        for at in [0usize, 4, 8, 20, 40, good.len() / 2, good.len() - 1] {
            let mut bad = good.clone();
            bad[at] ^= 0x5A;
            std::fs::write(&path, &bad).unwrap();
            assert!(
                matches!(store.load(&spec, &v, None), ArtifactLoad::Invalid { .. }),
                "byte {at} flipped must invalidate"
            );
        }
        std::fs::write(&path, &good).unwrap();
        assert!(matches!(store.load(&spec, &v, None), ArtifactLoad::Hit { .. }));
    }

    #[test]
    fn scan_finds_matching_vocab_only_and_skips_temp_files() {
        let store = temp_store("scan");
        let v = vocab();
        let other = Arc::new(tokenizer::bpe::synthetic_json_vocab(320));
        for (name, vv) in [("fig3", &v), ("json", &v), ("fig3", &other)] {
            let spec = ConstraintSpec::builtin(name);
            let engine = Engine::compile(spec.to_cfg().unwrap(), vv.clone()).unwrap();
            store.save(&spec, vv, None, &engine, &[]).unwrap();
        }
        // A stray temp file and a corrupt artifact.
        std::fs::write(store.dir().join("0000.tmp-1-1"), b"junk").unwrap();
        std::fs::write(store.dir().join("ffffffffffffffff.domino"), b"junk").unwrap();
        let (loaded, invalid) = store.scan(&v, usize::MAX);
        assert_eq!(loaded.len(), 2, "two artifacts match this vocab");
        assert_eq!(invalid, 1, "the corrupt .domino file is counted");
        let (loaded, _) = store.scan(&other, usize::MAX);
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded[0].label, "builtin:fig3");
        // The limit caps deserialization work for bounded registries.
        let (capped, _) = store.scan(&v, 1);
        assert_eq!(capped.len(), 1);
    }

    #[test]
    fn prior_record_round_trips_and_rejects_corruption() {
        let store = temp_store("prior");
        let mut m = SpeculativeModel::new(0.75);
        for _ in 0..4 {
            m.observe(9, 2);
        }
        m.observe_gram(9, &[2, 3]);
        assert!(store.load_prior(0xAB).is_none(), "missing prior is a clean miss");
        let path = store.save_prior(0xAB, &m).unwrap();
        assert!(path.exists());
        let got = store.load_prior(0xAB).expect("saved prior loads");
        assert_eq!(got.to_bytes(), m.to_bytes());
        assert!(!got.frozen, "loaded priors keep learning");
        // Another key: self-describing records refuse to serve it even if
        // the file were copied there.
        assert!(store.load_prior(0xCD).is_none());
        std::fs::copy(&path, store.prior_path_for(0xCD)).unwrap();
        assert!(store.load_prior(0xCD).is_none(), "key mismatch inside the record");
        // Corruption anywhere must degrade to None, never panic.
        let good = std::fs::read(&path).unwrap();
        for at in [0usize, 4, 8, 16, good.len() / 2, good.len() - 1] {
            let mut bad = good.clone();
            bad[at] ^= 0x5A;
            std::fs::write(&path, &bad).unwrap();
            assert!(store.load_prior(0xAB).is_none(), "byte {at} flipped must invalidate");
        }
        let mut truncated = good.clone();
        truncated.truncate(good.len() - 3);
        std::fs::write(&path, &truncated).unwrap();
        assert!(store.load_prior(0xAB).is_none());
        std::fs::write(&path, &good).unwrap();
        assert!(store.load_prior(0xAB).is_some());
        // Prior records don't confuse the engine warm-start scan.
        let (loaded, invalid) = store.scan(&vocab(), usize::MAX);
        assert!(loaded.is_empty() && invalid == 0, "{} {}", loaded.len(), invalid);
    }

    #[test]
    fn scan_index_reads_headers_only_and_defers_body_validation() {
        let store = temp_store("index");
        let v = vocab();
        let other = Arc::new(tokenizer::bpe::synthetic_json_vocab(320));
        let spec = ConstraintSpec::builtin("fig3");
        let engine = Engine::compile(spec.to_cfg().unwrap(), v.clone()).unwrap();
        let path = store.save(&spec, &v, None, &engine, &[]).unwrap();
        let other_engine = Engine::compile(spec.to_cfg().unwrap(), other.clone()).unwrap();
        store.save(&spec, &other, None, &other_engine, &[]).unwrap();
        // A stray temp file, a too-short artifact, and a body-corrupt
        // artifact whose prefix is intact.
        std::fs::write(store.dir().join("0000.tmp-1-1"), b"junk").unwrap();
        std::fs::write(store.dir().join("ffffffffffffffff.domino"), b"junk").unwrap();
        let good = std::fs::read(&path).unwrap();
        let mut body_corrupt = good.clone();
        let last = body_corrupt.len() - 1;
        body_corrupt[last] ^= 0x5A;
        let key = ConstraintSpec::builtin("json").build_fingerprint(v.fingerprint(), None);
        std::fs::write(store.path_for(key), &body_corrupt).unwrap();
        // Wrong-key contents under json's filename: the checksum is not
        // read at index time, so the file indexes under its header key
        // (fig3's) but load_keyed rejects it on demand.

        let (headers, invalid) = store.scan_index(&v);
        assert_eq!(invalid, 1, "only the prefix-unreadable file counts here");
        assert_eq!(headers.len(), 2, "fig3 plus the body-corrupt clone; other vocab skipped");
        for h in &headers {
            assert_eq!((h.vocab_fp, h.vocab_len), (v.fingerprint(), v.len() as u64));
        }
        let (headers, _) = store.scan_index(&other);
        assert_eq!(headers.len(), 1);
        // The deferred validation: the corrupt clone fails at load time.
        assert!(matches!(store.load_keyed(key, &v), ArtifactLoad::Invalid { .. }));
    }

    #[test]
    fn synthetic_corpus_indexes_and_loads_like_real_artifacts() {
        let store = temp_store("synthetic");
        let v = vocab();
        let spec = ConstraintSpec::builtin("fig3");
        let engine = Engine::compile(spec.to_cfg().unwrap(), v.clone()).unwrap();
        let keys = store.seed_synthetic_corpus(&engine, 25).unwrap();
        assert_eq!(keys.len(), 25);
        assert_eq!(keys.iter().collect::<std::collections::HashSet<_>>().len(), 25);
        let (headers, invalid) = store.scan_index(&v);
        assert_eq!((headers.len(), invalid), (25, 0));
        // Every synthetic file is a fully valid artifact under its key.
        assert!(matches!(store.load_keyed(keys[7], &v), ArtifactLoad::Hit { .. }));
        // Idempotent: re-seeding overwrites in place, no growth.
        store.seed_synthetic_corpus(&engine, 25).unwrap();
        let (headers, _) = store.scan_index(&v);
        assert_eq!(headers.len(), 25);
    }

    #[test]
    fn builtin_grammar_name_is_stable_in_label() {
        // Labels travel through save/load for diagnostics; check the
        // json grammar (regex-heavy) round-trips too.
        let store = temp_store("label");
        let v = vocab();
        let spec = ConstraintSpec::builtin("json");
        let engine = Engine::compile(spec.to_cfg().unwrap(), v.clone()).unwrap();
        store.save(&spec, &v, Some(0), &engine, &[]).unwrap();
        match store.load(&spec, &v, Some(0)) {
            ArtifactLoad::Hit { label, .. } => assert_eq!(label, "builtin:json"),
            _ => panic!("expected hit"),
        }
    }
}
