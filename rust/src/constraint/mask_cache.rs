//! State-keyed token-mask cache, shared across slots and requests.
//!
//! Structured output keeps revisiting the same checker states: every JSON
//! object in a batch passes through the same `(α, β)` fingerprints
//! (§3.6's speculation keys). A mask computed once for such a state is
//! valid for every other slot/request in the same state, so the engine
//! registry attaches one [`MaskCache`] to each compiled engine and
//! [`CachedChecker`] consults it before traversing trees (DOMINO) or
//! scanning the vocabulary (the online baseline).
//!
//! Cache keys are `(variant, state)`:
//! * `variant` encodes what *besides* checker state determines the mask —
//!   today the lookahead `k` ([`MaskCache::variant`]). DOMINO at `k = ∞`
//!   and the online baseline produce identical masks (property-tested in
//!   `rust/tests/prop_invariants.rs`), so they deliberately share the
//!   `∞` variant and each other's cached masks.
//! * `state` is [`Checker::mask_key`]'s fingerprint of the scanner +
//!   parser state (the mask-determining subset of `state_key` — DOMINO
//!   drops the last committed token, so states reached via different
//!   tokenizations of the same text share masks). It is a hash, so
//!   distinct states could in principle collide — the same trade the
//!   §3.6 speculation model already makes.
//!
//! Eviction is LRU by logical tick, scanned lazily on insert; the cache
//! is bounded, so a pathological workload degrades to recomputation, not
//! memory growth.

use crate::domino::decoder::Lookahead;
use crate::domino::{Checker, TokenMask};
use crate::TokenId;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Counters for one cache (or an aggregate over several — see
/// [`MaskCacheStats::merge`]).
#[derive(Clone, Debug, Default)]
pub struct MaskCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
}

impl MaskCacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn merge(&mut self, other: &MaskCacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.entries += other.entries;
    }
}

struct MaskEntry {
    mask: TokenMask,
    tick: u64,
}

struct MaskInner {
    map: HashMap<(u64, u64), MaskEntry>,
    tick: u64,
}

/// A bounded, concurrent `(variant, state) → TokenMask` cache.
pub struct MaskCache {
    capacity: usize,
    inner: Mutex<MaskInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl MaskCache {
    pub fn new(capacity: usize) -> MaskCache {
        assert!(capacity >= 1, "mask cache needs capacity >= 1");
        MaskCache {
            capacity,
            inner: Mutex::new(MaskInner { map: HashMap::new(), tick: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The cache variant for a DOMINO lookahead depth. The online
    /// baseline's masks equal DOMINO's at `k = ∞`, so it uses
    /// `variant(Lookahead::Infinite)`.
    pub fn variant(k: Lookahead) -> u64 {
        match k {
            Lookahead::K(k) => k as u64,
            Lookahead::Infinite => u64::MAX,
        }
    }

    /// Look up a mask, counting a hit or miss.
    pub fn get(&self, variant: u64, state: u64) -> Option<TokenMask> {
        let mut inner = self.inner.lock().expect("mask cache lock");
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(&(variant, state)) {
            Some(e) => {
                e.tick = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.mask.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Look up without touching the hit/miss counters (used by
    /// single-token checks, which probe on every sampled token: counting
    /// those would drown the compute-path hit rate the metrics exist to
    /// report — absence here falls through to a cheap direct check, not a
    /// mask computation).
    pub fn peek(&self, variant: u64, state: u64) -> Option<TokenMask> {
        let mut inner = self.inner.lock().expect("mask cache lock");
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.get_mut(&(variant, state)).map(|e| {
            e.tick = tick;
            e.mask.clone()
        })
    }

    /// Insert a computed mask, evicting the least-recently-used entries
    /// if the cache is full. Eviction drops the oldest ~1/8 of entries in
    /// one pass so the scan cost amortizes to O(log n) per insert instead
    /// of a full scan on every miss once the cache fills (this sits on
    /// the decode hot path, under the lock every slot shares).
    pub fn put(&self, variant: u64, state: u64, mask: TokenMask) {
        let mut inner = self.inner.lock().expect("mask cache lock");
        inner.tick += 1;
        let tick = inner.tick;
        if inner.map.len() >= self.capacity && !inner.map.contains_key(&(variant, state)) {
            let evict = (self.capacity / 8).max(1);
            let mut ticks: Vec<((u64, u64), u64)> =
                inner.map.iter().map(|(k, e)| (*k, e.tick)).collect();
            ticks.sort_unstable_by_key(|&(_, t)| t);
            for (k, _) in ticks.into_iter().take(evict) {
                inner.map.remove(&k);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        inner.map.insert((variant, state), MaskEntry { mask, tick });
    }

    /// Snapshot the hottest (most recently used) `limit` entries as
    /// `(variant, state, mask)` triples — the warm set persisted into an
    /// engine artifact so a restarted process starts with masks it
    /// already paid for.
    pub fn hot_entries(&self, limit: usize) -> Vec<(u64, u64, TokenMask)> {
        let inner = self.inner.lock().expect("mask cache lock");
        let mut all: Vec<(&(u64, u64), &MaskEntry)> = inner.map.iter().collect();
        all.sort_by(|a, b| b.1.tick.cmp(&a.1.tick));
        all.into_iter()
            .take(limit)
            .map(|(&(variant, state), e)| (variant, state, e.mask.clone()))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("mask cache lock").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> MaskCacheStats {
        MaskCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }
}

/// A [`Checker`] wrapper that reuses cached masks for states the shared
/// [`MaskCache`] has already seen. Wrap any checker whose
/// [`mask_key`](Checker::mask_key) is `Some`; checkers without a state
/// fingerprint pass straight through.
pub struct CachedChecker {
    inner: Box<dyn Checker>,
    cache: Arc<MaskCache>,
    variant: u64,
}

impl CachedChecker {
    pub fn new(inner: Box<dyn Checker>, cache: Arc<MaskCache>, variant: u64) -> CachedChecker {
        CachedChecker { inner, cache, variant }
    }

    pub fn cache(&self) -> &Arc<MaskCache> {
        &self.cache
    }
}

impl Checker for CachedChecker {
    fn advance(&mut self, token: TokenId) -> crate::Result<()> {
        self.inner.advance(token)
    }

    fn compute_mask(&mut self) -> TokenMask {
        let Some(state) = self.inner.mask_key() else {
            return self.inner.compute_mask();
        };
        if let Some(mask) = self.cache.get(self.variant, state) {
            return mask;
        }
        let mask = self.inner.compute_mask();
        self.cache.put(self.variant, state, mask.clone());
        mask
    }

    fn check_token(&mut self, token: TokenId) -> bool {
        // A cached mask answers single-token checks too — for the online
        // baseline this turns a scanner traversal into a bit test.
        if let Some(state) = self.inner.mask_key() {
            if let Some(mask) = self.cache.peek(self.variant, state) {
                return mask.allowed(token);
            }
        }
        self.inner.check_token(token)
    }

    fn reset(&mut self) {
        self.inner.reset()
    }

    fn state_key(&self) -> Option<u64> {
        self.inner.state_key()
    }

    fn mask_key(&self) -> Option<u64> {
        self.inner.mask_key()
    }

    fn check_bytes(&mut self, bytes: &[u8]) -> bool {
        self.inner.check_bytes(bytes)
    }

    fn advance_bytes(&mut self, bytes: &[u8]) -> crate::Result<()> {
        self.inner.advance_bytes(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask_with(size: usize, bits: &[TokenId]) -> TokenMask {
        let mut m = TokenMask::none(size);
        for &b in bits {
            m.allow(b);
        }
        m
    }

    #[test]
    fn get_put_roundtrip() {
        let c = MaskCache::new(4);
        assert!(c.get(0, 1).is_none());
        c.put(0, 1, mask_with(70, &[0, 64]));
        assert_eq!(c.get(0, 1).unwrap(), mask_with(70, &[0, 64]));
        // Same state under a different variant is a different entry.
        assert!(c.get(1, 1).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 1));
    }

    #[test]
    fn lru_evicts_oldest() {
        let c = MaskCache::new(2);
        c.put(0, 1, mask_with(8, &[1]));
        c.put(0, 2, mask_with(8, &[2]));
        assert!(c.get(0, 1).is_some()); // touch 1 → 2 is now oldest
        c.put(0, 3, mask_with(8, &[3]));
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.get(0, 2).is_none(), "entry 2 was LRU");
        assert!(c.get(0, 1).is_some());
        assert!(c.get(0, 3).is_some());
    }

    #[test]
    fn hot_entries_are_mru_first_and_bounded() {
        let c = MaskCache::new(8);
        c.put(0, 1, mask_with(8, &[1]));
        c.put(0, 2, mask_with(8, &[2]));
        c.put(0, 3, mask_with(8, &[3]));
        assert!(c.get(0, 1).is_some()); // touch 1 → hottest
        let hot = c.hot_entries(2);
        assert_eq!(hot.len(), 2);
        assert_eq!((hot[0].0, hot[0].1), (0, 1), "MRU first");
        assert_eq!((hot[1].0, hot[1].1), (0, 3));
        assert_eq!(hot[0].2, mask_with(8, &[1]));
        assert_eq!(c.hot_entries(100).len(), 3, "limit caps, never pads");
    }

    #[test]
    fn variant_encodes_lookahead() {
        assert_ne!(
            MaskCache::variant(Lookahead::K(0)),
            MaskCache::variant(Lookahead::Infinite)
        );
        assert_ne!(MaskCache::variant(Lookahead::K(0)), MaskCache::variant(Lookahead::K(1)));
    }
}
