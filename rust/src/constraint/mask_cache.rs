//! State-keyed token-mask cache, shared across slots and requests.
//!
//! Structured output keeps revisiting the same checker states: every JSON
//! object in a batch passes through the same `(α, β)` fingerprints
//! (§3.6's speculation keys). A mask computed once for such a state is
//! valid for every other slot/request in the same state, so the engine
//! registry attaches one [`MaskCache`] to each compiled engine and
//! [`CachedChecker`] consults it before traversing trees (DOMINO) or
//! scanning the vocabulary (the online baseline).
//!
//! Cache keys are `(variant, state)`:
//! * `variant` encodes what *besides* checker state determines the mask —
//!   today the lookahead `k` ([`MaskCache::variant`]). DOMINO at `k = ∞`
//!   and the online baseline produce identical masks (property-tested in
//!   `rust/tests/prop_invariants.rs`), so they deliberately share the
//!   `∞` variant and each other's cached masks.
//! * `state` is [`Checker::mask_key`]'s fingerprint of the scanner +
//!   parser state (the mask-determining subset of `state_key` — DOMINO
//!   drops the last committed token, so states reached via different
//!   tokenizations of the same text share masks). It is a hash, so
//!   distinct states could in principle collide — the same trade the
//!   §3.6 speculation model already makes.
//!
//! ## Concurrency layout
//!
//! The map is split into a power-of-two number of **shards**, each behind
//! its own `RwLock`, indexed by a cheap mix of `(variant, state)` —
//! concurrent slots in a batched tick hit different shards instead of
//! serializing on one lock. Lookups take only the *read* lock (recency
//! ticks are per-entry atomics, so a hit never needs exclusive access)
//! and entries are `Arc<TokenMask>`, so `get`/`peek`/`hot_entries` clone
//! a pointer, never a vocabulary-sized bitset, while holding the lock.
//!
//! Eviction is LRU by logical tick: `put` on a full shard drops the
//! oldest ~1/8 of that shard's entries in one pass, selected with a
//! bounded max-heap (O(n log k), no full sort under the lock). The cache
//! is bounded, so a pathological workload degrades to recomputation, not
//! memory growth.

use crate::domino::decoder::Lookahead;
use crate::domino::{Checker, TokenMask};
use crate::TokenId;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Counters for one cache (or an aggregate over several — see
/// [`MaskCacheStats::merge`]).
#[derive(Clone, Debug, Default)]
pub struct MaskCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
}

impl MaskCacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn merge(&mut self, other: &MaskCacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.entries += other.entries;
    }
}

struct MaskEntry {
    mask: Arc<TokenMask>,
    /// Last-touched logical time; atomic so read-lock holders can bump it.
    tick: AtomicU64,
}

/// Default shard count (power of two). Eight shards cover the batch
/// widths the scheduler runs (≤ 8–16 concurrent slots) with near-zero
/// collision probability while keeping per-shard capacity large enough
/// for LRU to be meaningful.
const DEFAULT_SHARDS: usize = 8;

/// A bounded, concurrent `(variant, state) → TokenMask` cache.
pub struct MaskCache {
    shards: Vec<RwLock<HashMap<(u64, u64), MaskEntry>>>,
    /// Capacity of each shard (total capacity / shard count, rounded up).
    per_shard: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl MaskCache {
    pub fn new(capacity: usize) -> MaskCache {
        // Shrink the shard count for tiny caches so total capacity stays
        // close to the requested bound (each shard holds ≥ 1 entry).
        let mut shards = DEFAULT_SHARDS;
        while shards > 1 && shards > capacity {
            shards /= 2;
        }
        Self::with_shards(capacity, shards)
    }

    /// Explicit shard count (power of two). `with_shards(cap, 1)` pins the
    /// single-lock layout — tests that assert exact LRU order use it, and
    /// the contention bench compares it against the sharded default.
    pub fn with_shards(capacity: usize, shards: usize) -> MaskCache {
        assert!(capacity >= 1, "mask cache needs capacity >= 1");
        assert!(shards >= 1 && shards.is_power_of_two(), "shard count must be a power of two");
        MaskCache {
            shards: (0..shards).map(|_| RwLock::new(HashMap::new())).collect(),
            per_shard: capacity.div_ceil(shards),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The cache variant for a DOMINO lookahead depth. The online
    /// baseline's masks equal DOMINO's at `k = ∞`, so it uses
    /// `variant(Lookahead::Infinite)`.
    pub fn variant(k: Lookahead) -> u64 {
        match k {
            Lookahead::K(k) => k as u64,
            Lookahead::Infinite => u64::MAX,
        }
    }

    /// Shard index: a splitmix64-style finalizer over the key so adjacent
    /// states spread across shards.
    fn shard_of(&self, variant: u64, state: u64) -> usize {
        let mut x = state ^ variant.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        (x as usize) & (self.shards.len() - 1)
    }

    fn lookup(&self, variant: u64, state: u64) -> Option<Arc<TokenMask>> {
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        let shard = self.shards[self.shard_of(variant, state)].read().expect("mask cache lock");
        shard.get(&(variant, state)).map(|e| {
            e.tick.store(tick, Ordering::Relaxed);
            e.mask.clone()
        })
    }

    /// Look up a mask, counting a hit or miss.
    pub fn get(&self, variant: u64, state: u64) -> Option<Arc<TokenMask>> {
        let found = self.lookup(variant, state);
        match found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Look up without touching the hit/miss counters (used by
    /// single-token checks, which probe on every sampled token: counting
    /// those would drown the compute-path hit rate the metrics exist to
    /// report — absence here falls through to a cheap direct check, not a
    /// mask computation).
    pub fn peek(&self, variant: u64, state: u64) -> Option<Arc<TokenMask>> {
        self.lookup(variant, state)
    }

    /// Insert a computed mask, evicting the least-recently-used entries
    /// of the target shard if it is full. Eviction drops the oldest ~1/8
    /// of the shard in one pass, selected with a size-bounded max-heap
    /// (O(n log k) scan, no allocation-heavy full sort) — this sits on
    /// the decode hot path.
    pub fn put(&self, variant: u64, state: u64, mask: Arc<TokenMask>) {
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        let mut shard =
            self.shards[self.shard_of(variant, state)].write().expect("mask cache lock");
        if shard.len() >= self.per_shard && !shard.contains_key(&(variant, state)) {
            let evict = (self.per_shard / 8).max(1);
            // Max-heap of the `evict` smallest ticks seen so far.
            let mut oldest: BinaryHeap<(u64, (u64, u64))> = BinaryHeap::with_capacity(evict + 1);
            for (k, e) in shard.iter() {
                oldest.push((e.tick.load(Ordering::Relaxed), *k));
                if oldest.len() > evict {
                    oldest.pop();
                }
            }
            for (_, k) in oldest {
                shard.remove(&k);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        shard.insert((variant, state), MaskEntry { mask, tick: AtomicU64::new(tick) });
    }

    /// Snapshot the hottest (most recently used) `limit` entries as
    /// `(variant, state, mask)` triples — the warm set persisted into an
    /// engine artifact so a restarted process starts with masks it
    /// already paid for.
    pub fn hot_entries(&self, limit: usize) -> Vec<(u64, u64, Arc<TokenMask>)> {
        let mut all: Vec<(u64, (u64, u64, Arc<TokenMask>))> = Vec::new();
        for lock in &self.shards {
            let shard = lock.read().expect("mask cache lock");
            all.extend(shard.iter().map(|(&(variant, state), e)| {
                (e.tick.load(Ordering::Relaxed), (variant, state, e.mask.clone()))
            }));
        }
        all.sort_by(|a, b| b.0.cmp(&a.0));
        all.into_iter().take(limit).map(|(_, entry)| entry).collect()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().expect("mask cache lock").len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> MaskCacheStats {
        MaskCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }
}

/// A [`Checker`] wrapper that reuses cached masks for states the shared
/// [`MaskCache`] has already seen. Wrap any checker whose
/// [`mask_key`](Checker::mask_key) is `Some`; checkers without a state
/// fingerprint pass straight through.
pub struct CachedChecker {
    inner: Box<dyn Checker>,
    cache: Arc<MaskCache>,
    variant: u64,
}

impl CachedChecker {
    pub fn new(inner: Box<dyn Checker>, cache: Arc<MaskCache>, variant: u64) -> CachedChecker {
        CachedChecker { inner, cache, variant }
    }

    pub fn cache(&self) -> &Arc<MaskCache> {
        &self.cache
    }
}

impl Checker for CachedChecker {
    fn advance(&mut self, token: TokenId) -> crate::Result<()> {
        self.inner.advance(token)
    }

    fn compute_mask(&mut self) -> Arc<TokenMask> {
        let Some(state) = self.inner.mask_key() else {
            return self.inner.compute_mask();
        };
        if let Some(mask) = self.cache.get(self.variant, state) {
            return mask;
        }
        let mask = self.inner.compute_mask();
        self.cache.put(self.variant, state, mask.clone());
        mask
    }

    fn check_token(&mut self, token: TokenId) -> bool {
        // A cached mask answers single-token checks too — for the online
        // baseline this turns a scanner traversal into a bit test.
        if let Some(state) = self.inner.mask_key() {
            if let Some(mask) = self.cache.peek(self.variant, state) {
                return mask.allowed(token);
            }
        }
        self.inner.check_token(token)
    }

    fn reset(&mut self) {
        self.inner.reset()
    }

    fn state_key(&self) -> Option<u64> {
        self.inner.state_key()
    }

    fn mask_key(&self) -> Option<u64> {
        self.inner.mask_key()
    }

    fn check_bytes(&mut self, bytes: &[u8]) -> bool {
        self.inner.check_bytes(bytes)
    }

    fn advance_bytes(&mut self, bytes: &[u8]) -> crate::Result<()> {
        self.inner.advance_bytes(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask_with(size: usize, bits: &[TokenId]) -> Arc<TokenMask> {
        let mut m = TokenMask::none(size);
        for &b in bits {
            m.allow(b);
        }
        Arc::new(m)
    }

    #[test]
    fn get_put_roundtrip() {
        let c = MaskCache::new(4);
        assert!(c.get(0, 1).is_none());
        c.put(0, 1, mask_with(70, &[0, 64]));
        assert_eq!(c.get(0, 1).unwrap(), mask_with(70, &[0, 64]));
        // Same state under a different variant is a different entry.
        assert!(c.get(1, 1).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 1));
    }

    #[test]
    fn lru_evicts_oldest() {
        // Single shard pins global LRU order (with several shards, LRU is
        // exact per shard).
        let c = MaskCache::with_shards(2, 1);
        c.put(0, 1, mask_with(8, &[1]));
        c.put(0, 2, mask_with(8, &[2]));
        assert!(c.get(0, 1).is_some()); // touch 1 → 2 is now oldest
        c.put(0, 3, mask_with(8, &[3]));
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.get(0, 2).is_none(), "entry 2 was LRU");
        assert!(c.get(0, 1).is_some());
        assert!(c.get(0, 3).is_some());
    }

    #[test]
    fn hot_entries_are_mru_first_and_bounded() {
        let c = MaskCache::new(8);
        c.put(0, 1, mask_with(8, &[1]));
        c.put(0, 2, mask_with(8, &[2]));
        c.put(0, 3, mask_with(8, &[3]));
        assert!(c.get(0, 1).is_some()); // touch 1 → hottest
        let hot = c.hot_entries(2);
        assert_eq!(hot.len(), 2);
        assert_eq!((hot[0].0, hot[0].1), (0, 1), "MRU first");
        assert_eq!((hot[1].0, hot[1].1), (0, 3));
        assert_eq!(*hot[0].2, *mask_with(8, &[1]));
        assert_eq!(c.hot_entries(100).len(), 3, "limit caps, never pads");
    }

    #[test]
    fn sharded_cache_keeps_per_key_consistency() {
        // Keys land on every shard; each must read back its own mask.
        let c = MaskCache::new(1024);
        assert!(c.shards.len() > 1, "default layout is sharded");
        for state in 0..64u64 {
            c.put(1, state, mask_with(130, &[(state % 100) as TokenId]));
        }
        for state in 0..64u64 {
            let got = c.get(1, state).expect("present");
            assert!(got.allowed((state % 100) as TokenId));
            assert_eq!(got.count(), 1);
        }
        let s = c.stats();
        assert_eq!(s.entries, 64);
        assert_eq!((s.hits, s.misses), (64, 0));
    }

    #[test]
    fn variant_encodes_lookahead() {
        assert_ne!(
            MaskCache::variant(Lookahead::K(0)),
            MaskCache::variant(Lookahead::Infinite)
        );
        assert_ne!(MaskCache::variant(Lookahead::K(0)), MaskCache::variant(Lookahead::K(1)));
    }
}
