//! Vocabulary: token id ↔ byte-string mapping + BPE merge-rank encoder.

use crate::util::Json;
use crate::TokenId;
use anyhow::{bail, Context};
use std::collections::HashMap;
use std::path::Path;

pub const EOS_ID: TokenId = 0;
pub const BOS_ID: TokenId = 1;
pub const PAD_ID: TokenId = 2;
/// Number of special tokens preceding the 256 byte tokens.
pub const NUM_SPECIAL: usize = 3;

// Serialized form (`artifacts/tokenizer.json`), shared with python:
// `{"merges": [[a, b], ...]}` — merge pairs in rank order, elements are
// token ids.

/// A byte-level BPE vocabulary.
#[derive(Clone, Debug)]
pub struct Vocab {
    /// Byte string of every token. Specials have empty byte strings.
    tokens: Vec<Vec<u8>>,
    /// Merge pair → resulting token id, with rank = id (lower id = earlier
    /// merge = higher priority).
    merge_map: HashMap<(TokenId, TokenId), TokenId>,
    merges: Vec<(TokenId, TokenId)>,
    /// Cached content fingerprint (cleared by `push_merge`).
    fp: std::sync::OnceLock<u64>,
}

impl Vocab {
    /// Base vocabulary: specials + 256 byte tokens, no merges.
    pub fn byte_level() -> Vocab {
        let mut tokens = vec![Vec::new(); NUM_SPECIAL];
        for b in 0u16..256 {
            tokens.push(vec![b as u8]);
        }
        Vocab {
            tokens,
            merge_map: HashMap::new(),
            merges: Vec::new(),
            fp: std::sync::OnceLock::new(),
        }
    }

    /// Deterministic FNV-1a content hash of the vocabulary (token count +
    /// every token's byte string, length-prefixed). Stable across
    /// processes — the vocab-identity component of engine-registry keys
    /// and on-disk artifact validation. Cached after the first call.
    pub fn fingerprint(&self) -> u64 {
        *self.fp.get_or_init(|| {
            let mut buf = Vec::with_capacity(self.tokens.len() * 8);
            buf.extend_from_slice(&(self.tokens.len() as u64).to_le_bytes());
            for t in &self.tokens {
                buf.extend_from_slice(&(t.len() as u64).to_le_bytes());
                buf.extend_from_slice(t);
            }
            crate::util::binio::fnv1a_64(&buf)
        })
    }

    /// Rebuild from a merge list (the serialized form).
    pub fn from_merges(merges: Vec<(TokenId, TokenId)>) -> crate::Result<Vocab> {
        let mut v = Vocab::byte_level();
        for (a, b) in merges {
            v.push_merge(a, b)?;
        }
        Ok(v)
    }

    pub(crate) fn push_merge(&mut self, a: TokenId, b: TokenId) -> crate::Result<TokenId> {
        let (au, bu) = (a as usize, b as usize);
        if au >= self.tokens.len() || bu >= self.tokens.len() {
            bail!("merge references unknown token ({a}, {b})");
        }
        if au < NUM_SPECIAL || bu < NUM_SPECIAL {
            bail!("merge references special token");
        }
        let mut bytes = self.tokens[au].clone();
        bytes.extend_from_slice(&self.tokens[bu]);
        let id = self.tokens.len() as TokenId;
        self.tokens.push(bytes);
        self.merge_map.insert((a, b), id);
        self.merges.push((a, b));
        self.fp = std::sync::OnceLock::new(); // content changed
        Ok(id)
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// Byte string of a token (empty for specials).
    pub fn token_bytes(&self, id: TokenId) -> &[u8] {
        &self.tokens[id as usize]
    }

    /// Lossy display form of a token.
    pub fn token_str(&self, id: TokenId) -> String {
        match id {
            EOS_ID => "<eos>".to_string(),
            BOS_ID => "<bos>".to_string(),
            PAD_ID => "<pad>".to_string(),
            _ => String::from_utf8_lossy(self.token_bytes(id)).into_owned(),
        }
    }

    /// BPE-encode a byte string: start from byte tokens, repeatedly apply
    /// the highest-priority (lowest-id) applicable merge.
    pub fn encode(&self, input: &[u8]) -> Vec<TokenId> {
        let mut ids: Vec<TokenId> =
            input.iter().map(|&b| (b as usize + NUM_SPECIAL) as TokenId).collect();
        if ids.len() < 2 {
            return ids;
        }
        loop {
            // Find the applicable merge with the lowest resulting id.
            let mut best: Option<(TokenId, usize)> = None;
            for i in 0..ids.len() - 1 {
                if let Some(&m) = self.merge_map.get(&(ids[i], ids[i + 1])) {
                    if best.map_or(true, |(bm, _)| m < bm) {
                        best = Some((m, i));
                    }
                }
            }
            let Some((merged, _)) = best else { break };
            // Apply this merge at every applicable position (left to right).
            let pair = self.merges[(merged as usize) - NUM_SPECIAL - 256];
            let mut out = Vec::with_capacity(ids.len());
            let mut i = 0;
            while i < ids.len() {
                if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
                    out.push(merged);
                    i += 2;
                } else {
                    out.push(ids[i]);
                    i += 1;
                }
            }
            ids = out;
            if ids.len() < 2 {
                break;
            }
        }
        ids
    }

    /// Decode token ids back to bytes (specials decode to nothing).
    pub fn decode(&self, ids: &[TokenId]) -> Vec<u8> {
        let mut out = Vec::new();
        for &id in ids {
            out.extend_from_slice(self.token_bytes(id));
        }
        out
    }

    pub fn decode_str(&self, ids: &[TokenId]) -> String {
        String::from_utf8_lossy(&self.decode(ids)).into_owned()
    }

    pub fn save(&self, path: &Path) -> crate::Result<()> {
        let merges = Json::Arr(
            self.merges
                .iter()
                .map(|&(a, b)| Json::Arr(vec![Json::Num(a as f64), Json::Num(b as f64)]))
                .collect(),
        );
        let file = Json::obj(vec![("merges", merges)]);
        std::fs::write(path, file.to_string())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> crate::Result<Vocab> {
        let data = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let file = Json::parse(&data)?;
        let merges = file
            .get("merges")
            .and_then(|m| m.as_arr())
            .context("tokenizer.json: missing `merges`")?;
        let pairs = merges
            .iter()
            .map(|p| {
                let p = p.as_arr().context("merge entry must be a pair")?;
                if p.len() != 2 {
                    bail!("merge entry must have 2 elements");
                }
                let a = p[0].as_f64().context("merge id")? as TokenId;
                let b = p[1].as_f64().context("merge id")? as TokenId;
                Ok((a, b))
            })
            .collect::<crate::Result<Vec<_>>>()?;
        Vocab::from_merges(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_level_roundtrip() {
        let v = Vocab::byte_level();
        assert_eq!(v.len(), NUM_SPECIAL + 256);
        let ids = v.encode(b"hello \xff");
        assert_eq!(ids.len(), 7);
        assert_eq!(v.decode(&ids), b"hello \xff");
    }

    #[test]
    fn merges_apply_in_rank_order() {
        let mut v = Vocab::byte_level();
        let h = (b'h' as usize + NUM_SPECIAL) as TokenId;
        let e = (b'e' as usize + NUM_SPECIAL) as TokenId;
        let l = (b'l' as usize + NUM_SPECIAL) as TokenId;
        let he = v.push_merge(h, e).unwrap();
        let ll = v.push_merge(l, l).unwrap();
        let hell = v.push_merge(he, ll).unwrap();
        let ids = v.encode(b"hello");
        let o = (b'o' as usize + NUM_SPECIAL) as TokenId;
        assert_eq!(ids, vec![hell, o]);
        assert_eq!(v.decode(&ids), b"hello");
        assert_eq!(v.token_bytes(hell), b"hell");
    }

    #[test]
    fn save_load_roundtrip() {
        let mut v = Vocab::byte_level();
        let a = (b'a' as usize + NUM_SPECIAL) as TokenId;
        v.push_merge(a, a).unwrap();
        let p = std::env::temp_dir().join(format!("domino_tok_test_{}.json", std::process::id()));
        v.save(&p).unwrap();
        let v2 = Vocab::load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(v2.len(), v.len());
        assert_eq!(v2.encode(b"aaaa"), v.encode(b"aaaa"));
    }

    #[test]
    fn fingerprint_is_content_keyed_and_merge_sensitive() {
        let a = Vocab::byte_level();
        let b = Vocab::byte_level();
        assert_eq!(a.fingerprint(), b.fingerprint(), "same content, same fingerprint");
        let mut c = Vocab::byte_level();
        let x = (b'x' as usize + NUM_SPECIAL) as TokenId;
        let fp_before = c.fingerprint();
        c.push_merge(x, x).unwrap();
        assert_ne!(c.fingerprint(), fp_before, "push_merge must invalidate the cache");
        // Clones carry the content (and thus the fingerprint).
        assert_eq!(c.clone().fingerprint(), c.fingerprint());
    }

    #[test]
    fn rejects_bad_merges() {
        assert!(Vocab::from_merges(vec![(0, 5)]).is_err()); // special
        assert!(Vocab::from_merges(vec![(9999, 5)]).is_err()); // unknown
    }
}
