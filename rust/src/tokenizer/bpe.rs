//! BPE trainer (greedy pair-frequency merging).
//!
//! Used by tests and benches to build deterministic synthetic vocabularies;
//! the serving vocabulary is trained by `python/compile/train.py` with the
//! same algorithm and loaded via [`super::Vocab::load`].

use super::vocab::{Vocab, NUM_SPECIAL};
use crate::TokenId;
use std::collections::HashMap;

/// Train a byte-level BPE on `corpus`, producing `vocab_size` total tokens
/// (specials + bytes + merges). Deterministic: ties broken by pair id.
/// Merged tokens are capped at 10 bytes (mirrors `data.py`: unbounded BPE
/// on a repetitive corpus merges boundary-spanning mega-tokens).
pub fn train(corpus: &[u8], vocab_size: usize) -> Vocab {
    const MAX_TOKEN_LEN: usize = 10;
    let mut vocab = Vocab::byte_level();
    let mut ids: Vec<TokenId> =
        corpus.iter().map(|&b| (b as usize + NUM_SPECIAL) as TokenId).collect();

    while vocab.len() < vocab_size {
        // Count adjacent pairs.
        let mut counts: HashMap<(TokenId, TokenId), usize> = HashMap::new();
        for w in ids.windows(2) {
            if vocab.token_bytes(w[0]).len() + vocab.token_bytes(w[1]).len() > MAX_TOKEN_LEN {
                continue;
            }
            *counts.entry((w[0], w[1])).or_insert(0) += 1;
        }
        // Most frequent pair; deterministic tie-break.
        let Some((&pair, &count)) = counts
            .iter()
            .max_by_key(|(&(a, b), &c)| (c, std::cmp::Reverse((a, b))))
        else {
            break;
        };
        if count < 2 {
            break; // nothing worth merging
        }
        let merged = vocab.push_merge(pair.0, pair.1).expect("valid merge");
        // Apply the merge to the working sequence.
        let mut out = Vec::with_capacity(ids.len());
        let mut i = 0;
        while i < ids.len() {
            if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
                out.push(merged);
                i += 2;
            } else {
                out.push(ids[i]);
                i += 1;
            }
        }
        ids = out;
    }
    vocab
}

/// A deterministic synthetic vocabulary trained on JSON-ish text — used by
/// unit tests and benches that need a realistic token inventory without the
/// build-time artifacts.
pub fn synthetic_json_vocab(vocab_size: usize) -> Vocab {
    let mut corpus = String::new();
    let names = ["John Doe", "Jane Roe", "Alice Li", "Bob Iger", "Eve Fox"];
    let jobs = ["engineer", "doctor", "teacher", "artist", "pilot"];
    for i in 0..200 {
        let name = names[i % names.len()];
        let job = jobs[(i / 5) % jobs.len()];
        corpus.push_str(&format!(
            "{{\n  \"name\": \"{name}\",\n  \"age\": {},\n  \"occupation\": \"{job}\",\n  \"score\": {}\n}}\n",
            20 + (i % 50),
            i * 3 % 100,
        ));
        corpus.push_str(&format!(
            "{{\"thoughts\": [{{\"step\": \"add {i}\", \"calculation\": \"{i} + {}\", \"result\": {}}}], \"answer\": {}}}\n",
            i + 1,
            2 * i + 1,
            2 * i + 1,
        ));
    }
    train(corpus.as_bytes(), vocab_size)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trains_and_roundtrips() {
        let corpus = b"the cat sat on the mat. the cat sat on the hat.".repeat(10);
        let v = train(&corpus, 300);
        assert!(v.len() > NUM_SPECIAL + 256, "learned at least one merge");
        assert!(v.len() <= 300);
        let ids = v.encode(&corpus);
        assert_eq!(v.decode(&ids), corpus);
        // Compression happened.
        assert!(ids.len() < corpus.len());
    }

    #[test]
    fn deterministic() {
        let corpus = b"abcabcabd".repeat(20);
        let a = train(&corpus, 280);
        let b = train(&corpus, 280);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.encode(b"abcabd"), b.encode(b"abcabd"));
    }

    #[test]
    fn synthetic_vocab_has_structural_tokens() {
        let v = synthetic_json_vocab(512);
        assert_eq!(v.len(), 512);
        // Multi-byte structural tokens must exist — these are exactly the
        // bridge tokens DOMINO's alignment is about (e.g. `":` or `",`).
        let has_bridge = (0..v.len() as TokenId).any(|id| {
            let b = v.token_bytes(id);
            b.len() >= 2 && b.iter().any(|&c| c == b'"') && b.iter().any(|&c| c == b':' || c == b',')
        });
        assert!(has_bridge, "expected a JSON bridge token in the synthetic vocab");
    }

    #[test]
    fn stops_when_no_repeats() {
        let v = train(b"abcdefg", 10_000);
        // No pair occurs twice → no merges.
        assert_eq!(v.len(), NUM_SPECIAL + 256);
    }
}
