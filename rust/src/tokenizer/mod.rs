//! Byte-level BPE tokenizer substrate.
//!
//! The LLM vocabulary is what DOMINO aligns grammars against, so the
//! tokenizer is a first-class substrate: a byte-level BPE with
//!
//! * a trainer ([`bpe::train`]) used by tests/benches to build synthetic
//!   vocabularies of any size,
//! * a merge-rank encoder and byte-concat decoder,
//! * JSON (de)serialization of the exact format `python/compile/aot.py`
//!   emits (`artifacts/tokenizer.json`) — python trains the serving
//!   tokenizer at build time, rust loads it at serve time.
//!
//! Token ids: `0 = EOS`, `1 = BOS`, `2 = PAD`, `3..259 = raw bytes`,
//! `259.. = merges`.

pub mod bpe;
pub mod vocab;

pub use bpe::train;
pub use vocab::{Vocab, EOS_ID, BOS_ID, PAD_ID, NUM_SPECIAL};
