//! Artifact-store integration: every invalidation path (truncation,
//! checksum corruption, version bump, vocab-fingerprint mismatch) must
//! fall back to a clean rebuild — never an error, never a stale engine —
//! and increment `artifact_invalid`; plus a concurrent load-dedup test
//! mirroring `integration_registry.rs`.

use domino::constraint::{ArtifactStore, ConstraintSpec, EngineRegistry};
use domino::tokenizer::{self, Vocab};
use std::path::PathBuf;
use std::sync::Arc;

fn vocab() -> Arc<Vocab> {
    Arc::new(tokenizer::bpe::synthetic_json_vocab(256))
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("domino_artifacts_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The single artifact file in `dir` (tests precompile exactly one).
fn only_artifact(dir: &PathBuf) -> PathBuf {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("domino"))
        .collect();
    assert_eq!(files.len(), 1, "expected exactly one artifact in {}", dir.display());
    files.pop().unwrap()
}

/// Precompile `spec` into a fresh store at `dir` and return the artifact
/// path.
fn precompile(dir: &PathBuf, spec: &ConstraintSpec, v: &Arc<Vocab>) -> PathBuf {
    let reg = EngineRegistry::with_store(8, ArtifactStore::new(dir.clone()).unwrap());
    reg.get_or_compile(spec, v, None).unwrap();
    only_artifact(dir)
}

/// After `corrupt` mangles the on-disk artifact, a fresh registry must
/// still serve the spec (clean rebuild), count exactly one invalid
/// artifact, and leave a *valid* artifact behind (the rebuild's
/// write-back overwrites the bad file).
fn assert_rebuilds_after(tag: &str, corrupt: impl Fn(&PathBuf)) {
    let dir = temp_dir(tag);
    let v = vocab();
    let spec = ConstraintSpec::builtin("fig3");
    let path = precompile(&dir, &spec, &v);
    corrupt(&path);

    let reg = EngineRegistry::with_store(8, ArtifactStore::new(dir.clone()).unwrap());
    let (engine, _) = reg.get_or_compile(&spec, &v, None).unwrap();
    assert!(engine.trees.total_nodes() > 0, "rebuilt engine is real");
    let s = reg.stats();
    assert_eq!(s.artifact_invalid, 1, "{tag}: the bad artifact must be counted: {s:?}");
    assert_eq!(s.artifact_hits, 0, "{tag}: the bad artifact must not be served");
    assert!(s.compile_ms > 0 || s.misses == 1, "{tag}: a clean rebuild happened");

    // The write-back replaced the corrupt file: a third boot loads clean.
    let reg = EngineRegistry::with_store(8, ArtifactStore::new(dir.clone()).unwrap());
    reg.get_or_compile(&spec, &v, None).unwrap();
    let s = reg.stats();
    assert_eq!((s.artifact_hits, s.artifact_invalid), (1, 0), "{tag}: rebuild was persisted");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_artifact_falls_back_to_rebuild() {
    assert_rebuilds_after("truncated", |path| {
        let data = std::fs::read(path).unwrap();
        std::fs::write(path, &data[..data.len() / 2]).unwrap();
    });
}

#[test]
fn checksum_mismatch_falls_back_to_rebuild() {
    assert_rebuilds_after("checksum", |path| {
        let mut data = std::fs::read(path).unwrap();
        let at = data.len() - 9; // deep in the payload
        data[at] ^= 0xFF;
        std::fs::write(path, &data).unwrap();
    });
}

#[test]
fn version_bump_falls_back_to_rebuild() {
    assert_rebuilds_after("version", |path| {
        let mut data = std::fs::read(path).unwrap();
        // The version is the u32 right after the 4-byte magic.
        data[4] = data[4].wrapping_add(1);
        std::fs::write(path, &data).unwrap();
    });
}

#[test]
fn vocab_fingerprint_mismatch_falls_back_to_rebuild() {
    // An artifact built against vocab A, surfaced under the key a vocab-B
    // build would look for (renamed on disk): the header's embedded vocab
    // fingerprint must reject it — a retrained tokenizer can never be
    // served a stale engine.
    let dir = temp_dir("vocabfp");
    let v_a = vocab();
    let v_b = Arc::new(tokenizer::bpe::synthetic_json_vocab(320));
    assert_ne!(v_a.fingerprint(), v_b.fingerprint());
    let spec = ConstraintSpec::builtin("fig3");
    let path_a = precompile(&dir, &spec, &v_a);
    let store = ArtifactStore::new(dir.clone()).unwrap();
    let key_b = spec.build_fingerprint(v_b.fingerprint(), None);
    std::fs::rename(&path_a, store.path_for(key_b)).unwrap();

    let reg = EngineRegistry::with_store(8, ArtifactStore::new(dir.clone()).unwrap());
    let (engine, _) = reg.get_or_compile(&spec, &v_b, None).unwrap();
    assert_eq!(engine.vocab.len(), v_b.len(), "the rebuild uses the live vocab");
    let s = reg.stats();
    assert_eq!(s.artifact_invalid, 1, "vocab mismatch must invalidate: {s:?}");
    assert_eq!(s.artifact_hits, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_artifact_loads_are_deduplicated() {
    // Mirror of integration_registry's concurrent-build dedup, with the
    // engine coming from disk: 8 racing requests must deserialize the
    // artifact exactly once and everyone shares that load.
    let dir = temp_dir("concurrent");
    let v = vocab();
    let spec = ConstraintSpec::builtin("json");
    precompile(&dir, &spec, &v);

    let registry = EngineRegistry::with_store(8, ArtifactStore::new(dir.clone()).unwrap());
    let mut handles = Vec::new();
    for _ in 0..8 {
        let registry = registry.clone();
        let vocab = v.clone();
        let spec = spec.clone();
        handles.push(std::thread::spawn(move || {
            registry.get_or_compile(&spec, &vocab, None).unwrap();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let s = registry.stats();
    assert_eq!(s.misses, 1, "exactly one in-memory miss under concurrency: {s:?}");
    assert_eq!(s.artifact_hits, 1, "the artifact deserialized exactly once: {s:?}");
    assert_eq!(s.hits + s.coalesced, 7, "everyone else reused the load: {s:?}");
    assert_eq!(s.compile_ms, 0, "nothing compiled");
    assert_eq!(s.entries, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_start_registers_every_valid_artifact_once() {
    let dir = temp_dir("warmstart");
    let v = vocab();
    // Two grammars, plus one artifact for a different vocab that must be
    // ignored by this process's scan.
    for name in ["fig3", "json"] {
        let reg = EngineRegistry::with_store(8, ArtifactStore::new(dir.clone()).unwrap());
        reg.get_or_compile(&ConstraintSpec::builtin(name), &v, None).unwrap();
    }
    let other = Arc::new(tokenizer::bpe::synthetic_json_vocab(320));
    let reg = EngineRegistry::with_store(8, ArtifactStore::new(dir.clone()).unwrap());
    reg.get_or_compile(&ConstraintSpec::builtin("fig3"), &other, None).unwrap();

    let reg = EngineRegistry::with_store(8, ArtifactStore::new(dir.clone()).unwrap());
    assert_eq!(reg.warm_start(&v), 2, "both artifacts for this vocab load");
    assert_eq!(reg.warm_start(&v), 0, "warm start is idempotent per process");
    assert!(reg.contains(&ConstraintSpec::builtin("fig3"), &v, None));
    assert!(reg.contains(&ConstraintSpec::builtin("json"), &v, None));
    assert!(!reg.contains(&ConstraintSpec::builtin("fig3"), &other, None));
    // Requests after warm start are pure in-memory hits.
    reg.get_or_compile(&ConstraintSpec::builtin("json"), &v, None).unwrap();
    let s = reg.stats();
    assert_eq!((s.misses, s.hits), (0, 1), "{s:?}");
    assert_eq!(s.warm_loaded, 2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flush_persists_hot_masks_for_the_next_boot() {
    use domino::constraint::MaskCache;
    use domino::domino::decoder::Lookahead;
    use domino::domino::Checker as _;
    use domino::domino::DominoDecoder;

    let dir = temp_dir("flush");
    let v = vocab();
    let spec = ConstraintSpec::builtin("json");
    let reg = EngineRegistry::with_store(8, ArtifactStore::new(dir.clone()).unwrap());
    let (engine, masks) = reg.get_or_compile(&spec, &v, None).unwrap();
    // Warm some masks through the cached path, then flush.
    let mut checker = domino::constraint::CachedChecker::new(
        Box::new(DominoDecoder::new(engine, Lookahead::Infinite)),
        masks.clone(),
        MaskCache::variant(Lookahead::Infinite),
    );
    for &id in &v.encode(b"{\"a\": 1") {
        checker.compute_mask();
        checker.advance(id).unwrap();
    }
    assert!(!masks.is_empty(), "masks were cached");
    assert_eq!(reg.flush_artifacts(), 1);

    // Next boot: the warm-started engine's cache is pre-seeded.
    let reg = EngineRegistry::with_store(8, ArtifactStore::new(dir.clone()).unwrap());
    assert_eq!(reg.warm_start(&v), 1);
    let (_, masks2) = reg.get_or_compile(&spec, &v, None).unwrap();
    assert!(
        masks2.len() >= masks.len().min(512),
        "persisted hot masks must survive the restart: {} < {}",
        masks2.len(),
        masks.len()
    );
    let _ = std::fs::remove_dir_all(&dir);
}
