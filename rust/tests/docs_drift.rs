//! Documentation drift gates.
//!
//! PROTOCOL.md documents the wire protocol field by field; these tests
//! pin that documentation to the code so a renamed or removed field
//! fails CI instead of rotting silently. The check is deliberately
//! one-directional (documented ⇒ exists): new fields may land with
//! their docs in the same PR, but docs may never describe a field the
//! parser does not know.

const PROTOCOL: &str = include_str!("../PROTOCOL.md");
const OPERATIONS: &str = include_str!("../OPERATIONS.md");
const ARCHITECTURE: &str = include_str!("../ARCHITECTURE.md");
const TCP_SRC: &str = include_str!("../src/server/tcp.rs");
const MAIN_SRC: &str = include_str!("../src/main.rs");

/// Extract the first-column backticked identifier from markdown table
/// rows (`| `name` | ... |`). Quoted values (error strings like
/// `"overloaded"`) and non-identifier cells are skipped — only plain
/// `[a-z0-9_]+` names count as wire fields.
fn table_field_names(doc: &str) -> Vec<String> {
    let mut names = Vec::new();
    for line in doc.lines() {
        let Some(rest) = line.strip_prefix("| `") else {
            continue;
        };
        let Some((name, _)) = rest.split_once('`') else {
            continue;
        };
        if !name.is_empty()
            && name
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        {
            names.push(name.to_string());
        }
    }
    names
}

#[test]
fn every_documented_wire_field_exists_in_tcp() {
    let names = table_field_names(PROTOCOL);
    // Sanity floor: if the extraction regresses (table format change),
    // fail loudly rather than silently checking nothing.
    assert!(
        names.len() >= 25,
        "extracted only {} field names from PROTOCOL.md tables — extraction broken?",
        names.len()
    );
    let missing: Vec<&String> = names
        .iter()
        .filter(|name| !TCP_SRC.contains(&format!("\"{name}\"")))
        .collect();
    assert!(
        missing.is_empty(),
        "PROTOCOL.md documents wire fields absent from src/server/tcp.rs: {missing:?}"
    );
}

#[test]
fn every_documented_cli_flag_exists_in_main() {
    // OPERATIONS.md's flag table cells look like `--queue-depth N`; the
    // flag parser in main.rs strips the dashes, so check the bare name.
    let mut flags = Vec::new();
    for line in OPERATIONS.lines() {
        let Some(rest) = line.strip_prefix("| `--") else {
            continue;
        };
        let Some((cell, _)) = rest.split_once('`') else {
            continue;
        };
        let name = cell.split_whitespace().next().unwrap_or("");
        if !name.is_empty() {
            flags.push(name.to_string());
        }
    }
    assert!(
        flags.len() >= 10,
        "extracted only {} flags from OPERATIONS.md — extraction broken?",
        flags.len()
    );
    let missing: Vec<&String> = flags
        .iter()
        .filter(|flag| !MAIN_SRC.contains(&format!("\"{flag}\"")))
        .collect();
    assert!(
        missing.is_empty(),
        "OPERATIONS.md documents CLI flags absent from src/main.rs: {missing:?}"
    );
}

#[test]
fn every_documented_error_reason_exists_in_engine() {
    // The Errors matrix documents each machine-readable `reason` value;
    // those strings live in engine.rs (Abort::reason / overloaded calls
    // in scheduler.rs) and, for connection-level aborts, in the gateway
    // reactor. Check against the whole server module source.
    let engine_src = concat!(
        include_str!("../src/server/engine.rs"),
        include_str!("../src/server/scheduler.rs"),
        include_str!("../src/server/reactor.rs"),
    );
    for reason in [
        "queue_full",
        "tenant_quota",
        "queued",
        "decoding",
        "client_cancel",
        "client_disconnect",
        "connection_limit",
        "idle_timeout",
        "read_timeout",
        "write_stall",
    ] {
        assert!(
            PROTOCOL.contains(&format!("`\"{reason}\"`")),
            "PROTOCOL.md no longer documents abort reason {reason:?}"
        );
        assert!(
            engine_src.contains(&format!("\"{reason}\"")),
            "documented abort reason {reason:?} not found in server sources"
        );
    }
}

#[test]
fn trace_surface_is_documented_everywhere() {
    // The tracing wire surface: every capture cause the server emits
    // (`cause` in trace dumps and the metric label) must be documented
    // in PROTOCOL.md and spelled identically in trace.rs, and every
    // tracing CLI flag must hold its row in OPERATIONS.md's table.
    let trace_src = include_str!("../src/server/trace.rs");
    for cause in ["sampled", "requested", "slow", "aborted"] {
        assert!(
            trace_src.contains(&format!("\"{cause}\"")),
            "capture cause {cause:?} not found in src/server/trace.rs"
        );
        assert!(
            PROTOCOL.contains(&format!("`{cause}`")),
            "PROTOCOL.md no longer documents trace capture cause {cause:?}"
        );
    }
    for flag in ["--trace-sample-rate", "--trace-slow-ms", "--trace-dir"] {
        assert!(
            OPERATIONS.contains(&format!("| `{flag}")),
            "OPERATIONS.md flag table lost {flag:?}"
        );
    }
}

#[test]
fn every_architecture_path_exists() {
    // ARCHITECTURE.md names source files in its module ↔ file table and
    // layer map; each `src/...` path it mentions must exist so the map
    // cannot describe a module that was moved or deleted.
    let manifest_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut paths = std::collections::BTreeSet::new();
    let mut rest = ARCHITECTURE;
    while let Some(start) = rest.find("src/") {
        let tail = &rest[start..];
        let end = tail
            .find(|c: char| !(c.is_ascii_alphanumeric() || c == '/' || c == '_' || c == '.'))
            .unwrap_or(tail.len());
        let path = tail[..end].trim_end_matches('.');
        if path.ends_with(".rs") {
            paths.insert(path.to_string());
        }
        rest = &rest[start + 4..];
    }
    // Sanity floor: the layer map + table should always name a healthy
    // number of files; near-zero means the extraction broke.
    assert!(
        paths.len() >= 20,
        "extracted only {} source paths from ARCHITECTURE.md — extraction broken?",
        paths.len()
    );
    let missing: Vec<&String> = paths
        .iter()
        .filter(|p| !manifest_dir.join(p).exists())
        .collect();
    assert!(
        missing.is_empty(),
        "ARCHITECTURE.md names source files that do not exist: {missing:?}"
    );
}
