//! Constraint-pipeline integration: ConstraintSpec → EngineRegistry →
//! CachedChecker → serving engine, over the mock LM.
//!
//! Covers the PR's acceptance criteria: two requests with the same
//! grammar compile the engine exactly once (asserted via registry
//! counters), warm-registry requests build no engine on the hot path,
//! and inline EBNF / regex / stop constraints work end-to-end through
//! the TCP request format.

use domino::constraint::{
    ArtifactStore, CachedChecker, Constraint, ConstraintSpec, EngineRegistry, MaskCache,
};
use domino::domino::decoder::Lookahead;
use domino::domino::{Checker, DominoDecoder};
use domino::runtime::mock::{json_mock, MockFactory};
use domino::server::engine::{EngineCtx, GenRequest, Server};
use domino::server::tcp::parse_request;

fn mock_server(slots: usize) -> Server {
    Server::start(
        move || {
            let (vocab, model) = json_mock(512);
            Ok(EngineCtx::new(Box::new(MockFactory { model }), vocab))
        },
        slots,
    )
}

/// A single-shard server whose registry is backed by the artifact store
/// at `dir` (the warm-start scan runs inside `EngineCtx::with_registry`).
fn mock_server_with_artifacts(dir: std::path::PathBuf) -> Server {
    Server::start(
        move || {
            let (vocab, model) = json_mock(512);
            let registry = EngineRegistry::with_store(8, ArtifactStore::new(dir)?);
            Ok(EngineCtx::with_registry(Box::new(MockFactory { model }), vocab, registry))
        },
        2,
    )
}

#[test]
fn same_grammar_compiles_exactly_once() {
    let server = mock_server(2);
    let req = GenRequest {
        prompt: String::new(),
        constraint: Constraint::domino(ConstraintSpec::builtin("json")),
        max_tokens: 16,
        ..Default::default()
    };
    let r1 = server.generate(req.clone()).unwrap();
    assert!(r1.error.is_none(), "{:?}", r1.error);
    let r2 = server.generate(req.clone()).unwrap();
    assert!(r2.error.is_none(), "{:?}", r2.error);
    // A differently-phrased spec of the same grammar also hits the cache.
    let r3 = server
        .generate(GenRequest {
            constraint: Constraint::domino(ConstraintSpec::builtin(" JSON ")),
            max_tokens: 8,
            ..req
        })
        .unwrap();
    assert!(r3.error.is_none(), "{:?}", r3.error);

    let m = server.metrics().unwrap();
    assert_eq!(m.registry_misses, 1, "the grammar must compile exactly once");
    assert_eq!(m.registry_hits, 2, "warm requests must reuse the engine");
    assert!(m.engine_compile_ms < u64::MAX);
    server.shutdown();
}

#[test]
fn concurrent_builds_are_deduplicated() {
    let (vocab, _) = json_mock(512);
    let registry = EngineRegistry::new(8);
    let spec = ConstraintSpec::builtin("json");
    let mut handles = Vec::new();
    for _ in 0..8 {
        let registry = registry.clone();
        let vocab = vocab.clone();
        let spec = spec.clone();
        handles.push(std::thread::spawn(move || {
            registry.get_or_compile(&spec, &vocab, None).unwrap();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let s = registry.stats();
    assert_eq!(s.misses, 1, "exactly one compile under concurrency: {s:?}");
    assert_eq!(s.hits + s.coalesced, 7, "everyone else reused it: {s:?}");
    assert_eq!(s.entries, 1);
}

#[test]
fn lru_eviction_is_bounded_and_counted() {
    let (vocab, _) = json_mock(512);
    let registry = EngineRegistry::new(2);
    for name in ["fig3", "json", "gsm8k"] {
        registry.get_or_compile(&ConstraintSpec::builtin(name), &vocab, None).unwrap();
    }
    let s = registry.stats();
    assert_eq!((s.misses, s.evictions, s.entries), (3, 1, 2));
    // The oldest entry (fig3) was evicted; the newer two are still warm.
    assert!(!registry.contains(&ConstraintSpec::builtin("fig3"), &vocab, None));
    assert!(registry.contains(&ConstraintSpec::builtin("json"), &vocab, None));
    assert!(registry.contains(&ConstraintSpec::builtin("gsm8k"), &vocab, None));
}

#[test]
fn inline_ebnf_end_to_end_via_tcp_format() {
    let req = parse_request(
        r#"{"prompt": "", "ebnf": "root ::= \"ab\"", "method": "domino-full", "max_tokens": 8}"#,
    )
    .unwrap();
    let server = mock_server(1);
    let r = server.generate(req).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.text, "ab", "grammar admits exactly the string `ab`");
    assert!(r.stats.stopped, "EOS is forced once the parse completes");
    let m = server.metrics().unwrap();
    assert_eq!(m.registry_misses, 1, "inline grammar compiled via the registry");
    server.shutdown();
}

#[test]
fn regex_constraint_end_to_end_via_tcp_format() {
    let req =
        parse_request(r#"{"prompt": "", "regex": "[0-9]{4}", "max_tokens": 16}"#).unwrap();
    let server = mock_server(1);
    let r = server.generate(req).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert!(r.stats.stopped, "exactly-4-digits regex must complete: {:?}", r.text);
    assert_eq!(r.text.len(), 4, "{:?}", r.text);
    assert!(r.text.chars().all(|c| c.is_ascii_digit()), "{:?}", r.text);
    server.shutdown();
}

#[test]
fn stop_sequence_end_to_end_via_tcp_format() {
    // The mock LM emits JSON-ish text; stop at the first closing brace.
    let req = parse_request(r#"{"prompt": "", "stop": ["}"], "max_tokens": 64}"#).unwrap();
    let server = mock_server(1);
    let r = server.generate(req).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    if r.stats.stopped {
        let first = r.text.find('}').expect("stopped output contains the stop sequence");
        // Nothing but (at most) the tail of the final token follows it.
        assert!(r.text.len() - first <= 16, "output continued past the stop: {:?}", r.text);
    }
    server.shutdown();
}

#[test]
fn cached_masks_equal_uncached_and_hit() {
    let (vocab, _) = json_mock(512);
    let registry = EngineRegistry::new(4);
    let (engine, masks) =
        registry.get_or_compile(&ConstraintSpec::builtin("json"), &vocab, None).unwrap();
    let mut plain = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
    let mut cached = CachedChecker::new(
        Box::new(DominoDecoder::new(engine, Lookahead::Infinite)),
        masks.clone(),
        MaskCache::variant(Lookahead::Infinite),
    );
    let ids = vocab.encode(b"{\"name\": \"Jo");
    for &id in &ids {
        let want = plain.compute_mask();
        assert_eq!(want, cached.compute_mask(), "first (miss) computation");
        assert_eq!(want, cached.compute_mask(), "second (hit) lookup");
        // Single-token checks answered from the cached mask agree too.
        for t in [0u32, 5, 100, 300, id] {
            assert_eq!(want.allowed(t), cached.check_token(t), "token {t}");
        }
        plain.advance(id).unwrap();
        cached.advance(id).unwrap();
    }
    let s = masks.stats();
    assert!(s.hits as usize >= ids.len(), "{s:?}");
    assert!(s.misses >= 1, "{s:?}");
    assert!(registry.mask_stats().hits >= s.hits, "registry aggregates live caches");
}

#[test]
fn kill_and_restart_serves_first_request_without_recompiling() {
    let dir = std::env::temp_dir()
        .join(format!("domino_restart_roundtrip_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let req = GenRequest {
        prompt: String::new(),
        constraint: Constraint::domino(ConstraintSpec::builtin("json")),
        max_tokens: 12,
        ..Default::default()
    };

    // First life: cold boot — the grammar compiles and its artifact is
    // written back to the store.
    let server = mock_server_with_artifacts(dir.clone());
    let r = server.generate(req.clone()).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    let m = server.metrics().unwrap();
    assert_eq!(m.registry_misses, 1, "cold boot compiles");
    assert_eq!(m.artifact_hits, 0, "nothing to load on the first life");
    assert_eq!(m.artifact_misses, 1, "the store was consulted before compiling");
    server.shutdown(); // the "kill"

    // Second life: the warm-start scan registers the persisted engine, so
    // the first request is an in-memory registry hit — no compile at all.
    let server = mock_server_with_artifacts(dir.clone());
    let r = server.generate(req).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    let m = server.metrics().unwrap();
    assert!(m.artifact_hits >= 1, "restart must boot from the artifact: {m:?}");
    assert_eq!(m.warm_start_loaded, 1, "warm start registered the engine");
    assert_eq!(m.registry_misses, 0, "first request after restart must not recompile");
    assert_eq!(m.engine_compile_ms, 0, "zero compile latency after restart");
    assert_eq!(m.registry_hits, 1, "the request was served from the warm registry");
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mask_cache_is_shared_across_requests() {
    // Two identical constrained requests: the second should mostly hit
    // masks cached by the first (mock LM + greedy → same states).
    let server = mock_server(1);
    let req = GenRequest {
        prompt: String::new(),
        // k=0 forces interventions → per-step mask computations.
        constraint: Constraint::domino(ConstraintSpec::builtin("json"))
            .with_lookahead(Some(0))
            .with_full_mask(),
        max_tokens: 12,
        ..Default::default()
    };
    let r1 = server.generate(req.clone()).unwrap();
    assert!(r1.error.is_none(), "{:?}", r1.error);
    let m1 = server.metrics().unwrap();
    let r2 = server.generate(req).unwrap();
    assert!(r2.error.is_none(), "{:?}", r2.error);
    let m2 = server.metrics().unwrap();
    let new_hits = m2.mask_cache_hits - m1.mask_cache_hits;
    let new_misses = m2.mask_cache_misses - m1.mask_cache_misses;
    assert!(
        new_hits > new_misses,
        "second request should reuse the first one's masks: +{new_hits} hits, +{new_misses} misses"
    );
    server.shutdown();
}
