//! Batched cross-slot stepping: token parity with the per-slot path
//! (plain, speculative, drafted and healing-phase slots in one batch),
//! degenerate single-slot batches, and per-slot failure isolation.

use domino::constraint::{Constraint, ConstraintSpec};
use domino::domino::generate::Prompt;
use domino::runtime::mock::{json_mock, MockFactory};
use domino::runtime::sampler::Sampling;
use domino::runtime::{LmBackend, LmSession};
use domino::server::engine::{EngineCtx, GenRequest, Server};
use domino::server::slot::{step_batched, Slot};
use domino::tokenizer::Vocab;
use domino::TokenId;
use std::sync::Arc;

const MAX_TOKENS: usize = 24;

fn mixed_shapes() -> Vec<(Constraint, &'static str)> {
    let json = ConstraintSpec::builtin("json");
    vec![
        // Plain grammar-constrained.
        (Constraint::domino(json.clone()), ""),
        // Speculative mid-proposal.
        (Constraint::domino(json.clone()).with_speculation(8), ""),
        // Healing phase: the prompt ends mid-token, so admission forces a
        // byte prefix and the slot starts with an output overhang.
        (Constraint::domino(json.clone()).with_speculation(8), "{\"na"),
        // Drafted: grammar-pruned multi-token proposals from the prior.
        (Constraint::domino(json.clone()).with_draft(6), ""),
        // Drafted with a healing phase.
        (Constraint::domino(json.clone()).with_draft(3), "{\"na"),
        // Full-mask variant.
        (Constraint::domino(json).with_full_mask(), ""),
        // Unconstrained.
        (Constraint::none(), ""),
    ]
}

fn make_slots(ctx: &mut EngineCtx, shapes: &[(Constraint, &'static str)], n: usize) -> Vec<Slot> {
    (0..n)
        .map(|i| {
            let (constraint, prompt) = &shapes[i % shapes.len()];
            let mode = ctx.decode_mode(constraint).unwrap();
            let session = ctx.backend.new_session().unwrap();
            let prompt = Prompt::healed(&ctx.vocab, prompt);
            Slot::new(
                i as u64,
                session,
                mode,
                ctx.vocab.clone(),
                &prompt,
                Sampling::Temperature(1.0),
                MAX_TOKENS,
                i as u64,
            )
            .unwrap()
        })
        .collect()
}

fn run_per_slot(slots: &mut [Slot]) {
    while slots.iter().any(|s| !s.done) {
        for s in slots.iter_mut() {
            s.step().unwrap();
        }
    }
}

fn run_batched(backend: &dyn LmBackend, slots: &mut [Slot]) {
    while slots.iter().any(|s| !s.done) {
        let mut view: Vec<&mut Slot> = slots.iter_mut().collect();
        let tick = step_batched(backend, &mut view);
        for r in &tick.results {
            assert!(r.is_ok(), "unexpected slot failure: {:?}", r.as_ref().err());
        }
    }
}

#[test]
fn mixed_batch_token_identical_to_per_slot() {
    let (vocab, model) = json_mock(512);
    let mut ctx = EngineCtx::new(Box::new(MockFactory { model: model.clone() }), vocab);
    let shapes = mixed_shapes();
    let mut a = make_slots(&mut ctx, &shapes, 8);
    let mut b = make_slots(&mut ctx, &shapes, 8);
    run_per_slot(&mut a);
    run_batched(&MockFactory { model }, &mut b);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.text(), y.text(), "slot {i} diverged");
        assert_eq!(x.out, y.out, "slot {i} token ids diverged");
        assert_eq!(x.stats.tokens_out, y.stats.tokens_out, "slot {i} token counts diverged");
        // NOTE: model_calls is deliberately NOT compared for the mixed
        // batch — speculative proposal lengths depend on the shared
        // prior's observation order, which the two interleavings visit
        // differently; the committed token stream is invariant to it.
    }
}

#[test]
fn single_slot_degenerate_batch_matches_step() {
    let (vocab, model) = json_mock(512);
    let mut ctx = EngineCtx::new(Box::new(MockFactory { model: model.clone() }), vocab);
    let shapes = [(Constraint::domino(ConstraintSpec::builtin("json")), "")];
    let mut a = make_slots(&mut ctx, &shapes, 1);
    let mut b = make_slots(&mut ctx, &shapes, 1);
    run_per_slot(&mut a);
    run_batched(&MockFactory { model }, &mut b);
    assert_eq!(a[0].text(), b[0].text());
    assert!(!b[0].text().is_empty(), "degenerate batch must still decode");
    // Plain (non-speculative) decoding pays exactly one forward
    // participation per committed step on either path.
    assert_eq!(a[0].stats.model_calls, b[0].stats.model_calls);
}

/// An LM session that errors after `fail_after` forward passes. No
/// `as_any_mut` override, so the batched backend routes it through the
/// sequential per-lane fallback — exactly what a foreign session gets.
struct FailingSession {
    inner: Box<dyn LmSession>,
    calls: usize,
    fail_after: usize,
}

impl LmSession for FailingSession {
    fn vocab_size(&self) -> usize {
        self.inner.vocab_size()
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn append(&mut self, tokens: &[TokenId]) -> domino::Result<Vec<f32>> {
        self.calls += 1;
        anyhow::ensure!(self.calls <= self.fail_after, "injected model failure");
        self.inner.append(tokens)
    }

    fn append_scored(&mut self, tokens: &[TokenId]) -> domino::Result<Vec<Vec<f32>>> {
        self.calls += 1;
        anyhow::ensure!(self.calls <= self.fail_after, "injected model failure");
        self.inner.append_scored(tokens)
    }

    fn rollback(&mut self, n: usize) -> domino::Result<()> {
        self.inner.rollback(n)
    }
}

#[test]
fn mid_batch_slot_error_does_not_poison_siblings() {
    let (vocab, model) = json_mock(512);
    let backend = MockFactory { model: model.clone() };
    let mut ctx = EngineCtx::new(Box::new(MockFactory { model: model.clone() }), vocab.clone());
    let shapes = [(Constraint::domino(ConstraintSpec::builtin("json")), "")];
    // Reference: three healthy slots, batched, no failure injected.
    let mut want = make_slots(&mut ctx, &shapes, 3);
    run_batched(&backend, &mut want);

    // Same three healthy slots + one slot whose session dies mid-decode.
    let mut slots = make_slots(&mut ctx, &shapes, 3);
    let failing_mode = ctx.decode_mode(&shapes[0].0).unwrap();
    let failing_session = Box::new(FailingSession {
        inner: ctx.backend.new_session().unwrap(),
        calls: 0,
        fail_after: 4,
    });
    let prompt = Prompt::healed(&vocab, "");
    slots.push(
        Slot::new(
            99,
            failing_session,
            failing_mode,
            vocab,
            &prompt,
            Sampling::Temperature(1.0),
            MAX_TOKENS,
            99,
        )
        .unwrap(),
    );

    let mut failed = false;
    for _ in 0..(MAX_TOKENS * 4) {
        if slots.iter().all(|s| s.done) {
            break;
        }
        let mut view: Vec<&mut Slot> = slots.iter_mut().collect();
        let tick = step_batched(&backend, &mut view);
        for (i, r) in tick.results.iter().enumerate() {
            if let Err(e) = r {
                assert_eq!(i, 3, "only the failing slot may error");
                assert!(format!("{e:#}").contains("injected model failure"), "{e:#}");
                failed = true;
            }
        }
    }
    assert!(failed, "the injected failure must surface");
    assert!(slots[3].done, "failing slot must be retired");
    // Siblings decode to completion with output identical to the
    // failure-free run: the dead lane never poisons the batch.
    for (i, (got, ref_slot)) in slots.iter().take(3).zip(&want).enumerate() {
        assert!(got.done, "sibling {i} must finish");
        assert_eq!(got.text(), ref_slot.text(), "sibling {i} output changed");
        assert!(!got.text().is_empty(), "sibling {i} must produce output");
    }
}

#[test]
fn drafted_mix_survives_mid_batch_lane_failure() {
    // ISSUE 7 bar: drafted, speculative and plain slots share one batched
    // tick; a drafted lane dying mid-decode must not perturb any sibling.
    let (vocab, model) = json_mock(512);
    let backend = MockFactory { model: model.clone() };
    let mut ctx = EngineCtx::new(Box::new(MockFactory { model: model.clone() }), vocab.clone());
    let json = ConstraintSpec::builtin("json");
    let shapes = [
        (Constraint::domino(json.clone()).with_draft(6), ""),
        (Constraint::domino(json.clone()).with_speculation(8), ""),
        (Constraint::domino(json.clone()), ""),
    ];
    // Reference: the three healthy lanes batched, no failure injected.
    let mut want = make_slots(&mut ctx, &shapes, 3);
    run_batched(&backend, &mut want);

    // Same three + a drafted slot whose session dies mid-decode.
    let mut slots = make_slots(&mut ctx, &shapes, 3);
    let failing_mode = ctx.decode_mode(&shapes[0].0).unwrap();
    let failing_session = Box::new(FailingSession {
        inner: ctx.backend.new_session().unwrap(),
        calls: 0,
        fail_after: 4,
    });
    let prompt = Prompt::healed(&vocab, "");
    slots.push(
        Slot::new(
            99,
            failing_session,
            failing_mode,
            vocab,
            &prompt,
            Sampling::Temperature(1.0),
            MAX_TOKENS,
            99,
        )
        .unwrap(),
    );

    let mut failed = false;
    for _ in 0..(MAX_TOKENS * 4) {
        if slots.iter().all(|s| s.done) {
            break;
        }
        let mut view: Vec<&mut Slot> = slots.iter_mut().collect();
        let tick = step_batched(&backend, &mut view);
        for (i, r) in tick.results.iter().enumerate() {
            if let Err(e) = r {
                assert_eq!(i, 3, "only the failing drafted slot may error");
                assert!(format!("{e:#}").contains("injected model failure"), "{e:#}");
                failed = true;
            }
        }
    }
    assert!(failed, "the injected failure must surface");
    assert!(slots[3].done, "failing slot must be retired");
    let mut drafted_work = 0usize;
    for (i, (got, ref_slot)) in slots.iter().take(3).zip(&want).enumerate() {
        assert!(got.done, "sibling {i} must finish");
        assert_eq!(got.text(), ref_slot.text(), "sibling {i} output changed");
        assert!(!got.text().is_empty(), "sibling {i} must produce output");
        drafted_work += got.stats.draft_proposed;
    }
    // The drafted sibling actually exercised the draft lane (the shared
    // prior was trained by the reference run above).
    assert!(drafted_work > 0, "drafted sibling never proposed");
}

#[test]
fn server_batched_output_matches_manual_per_slot() {
    let (vocab, model) = json_mock(512);
    // Manual per-slot reference with the same request parameters the
    // server maps at admission (healed prompt, temperature, seed).
    let mut ctx = EngineCtx::new(Box::new(MockFactory { model: model.clone() }), vocab.clone());
    let shapes = mixed_shapes();
    let mut reference = make_slots(&mut ctx, &shapes, shapes.len());
    run_per_slot(&mut reference);

    let server = {
        let vocab: Arc<Vocab> = vocab.clone();
        let model = model.clone();
        Server::start(move || Ok(EngineCtx::new(Box::new(MockFactory { model }), vocab)), 8)
    };
    let handles: Vec<_> = shapes
        .iter()
        .enumerate()
        .map(|(i, (constraint, prompt))| {
            server.submit(GenRequest {
                prompt: (*prompt).to_string(),
                constraint: constraint.clone(),
                max_tokens: MAX_TOKENS,
                temperature: Some(1.0),
                seed: i as u64,
                ..Default::default()
            })
        })
        .collect();
    for (i, (h, want)) in handles.into_iter().zip(&reference).enumerate() {
        let resp = h.recv().unwrap();
        assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
        assert_eq!(resp.text, want.text(), "request {i} diverged from per-slot path");
    }
    let m = server.metrics().unwrap();
    assert!(m.forward_batches > 0, "engine must run batched forward passes");
    assert!(m.forward_rows >= m.forward_batches, "each batch forwards at least one lane");
    assert!(m.batch_size.count > 0, "batch width histogram must record");
    server.shutdown();
}
