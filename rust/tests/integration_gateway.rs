//! Gateway-reactor integration over real sockets: byte-parity against
//! the legacy thread-per-connection path, an idle-connection soak with
//! live decode traffic and metrics consistency, admission-cap refusals,
//! idle/read timeouts (including the metrics slow-loris regression),
//! partial-frame reassembly, half-close, and graceful drain.

use domino::runtime::mock::{json_mock, MockFactory};
use domino::runtime::{LmFactory, LmSession};
use domino::server::engine::EngineCtx;
use domino::server::reactor::{Reactor, ReactorConfig};
use domino::server::scheduler::{Scheduler, SchedulerConfig};
use domino::server::tcp;
use domino::util::Json;
use domino::TokenId;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn mock_sched(engines: usize, slots: usize) -> Scheduler {
    let (vocab, model) = json_mock(512);
    Scheduler::start(
        move |_shard, registry| {
            Ok(EngineCtx::with_registry(
                Box::new(MockFactory { model: model.clone() }),
                vocab.clone(),
                registry,
            ))
        },
        SchedulerConfig { engines, slots_per_engine: slots, ..SchedulerConfig::default() },
    )
}

/// An LM whose every forward pass takes `delay` — slow enough to observe
/// a drain racing an in-flight stream.
struct SlowFactory {
    inner: MockFactory,
    delay: Duration,
}

struct SlowSession {
    inner: Box<dyn LmSession>,
    delay: Duration,
}

impl LmFactory for SlowFactory {
    fn vocab_size(&self) -> usize {
        self.inner.vocab_size()
    }

    fn new_session(&self) -> domino::Result<Box<dyn LmSession>> {
        Ok(Box::new(SlowSession { inner: self.inner.new_session()?, delay: self.delay }))
    }
}

impl LmSession for SlowSession {
    fn vocab_size(&self) -> usize {
        self.inner.vocab_size()
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn append(&mut self, tokens: &[TokenId]) -> domino::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        self.inner.append(tokens)
    }

    fn append_scored(&mut self, tokens: &[TokenId]) -> domino::Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.delay);
        self.inner.append_scored(tokens)
    }

    fn rollback(&mut self, n: usize) -> domino::Result<()> {
        self.inner.rollback(n)
    }
}

fn slow_sched(delay_ms: u64) -> Scheduler {
    let (vocab, model) = json_mock(512);
    Scheduler::start(
        move |_shard, registry| {
            Ok(EngineCtx::with_registry(
                Box::new(SlowFactory {
                    inner: MockFactory { model: model.clone() },
                    delay: Duration::from_millis(delay_ms),
                }),
                vocab.clone(),
                registry,
            ))
        },
        SchedulerConfig { engines: 1, slots_per_engine: 2, ..SchedulerConfig::default() },
    )
}

/// Send one streaming request and collect (event lines, final object).
fn stream_once(addr: SocketAddr, req: &str) -> (Vec<String>, Json) {
    let mut conn = TcpStream::connect(addr).unwrap();
    writeln!(conn, "{req}").unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    collect_stream(reader)
}

fn collect_stream(reader: BufReader<TcpStream>) -> (Vec<String>, Json) {
    let mut events = Vec::new();
    for line in reader.lines() {
        let line = line.unwrap();
        let v = Json::parse(&line).unwrap();
        if v.get("token").is_some() {
            events.push(line);
        } else {
            return (events, v);
        }
    }
    panic!("stream ended without a final stats object");
}

/// The reactor and the legacy thread-per-connection path must produce
/// identical streams for identical requests: the same event lines byte
/// for byte, and the same final text/token counts (`elapsed_s` is the
/// only nondeterministic response field).
#[test]
fn reactor_matches_threaded_path_byte_for_byte() {
    let reactor_sched = Arc::new(mock_sched(1, 2));
    let threaded_sched = Arc::new(mock_sched(1, 2));
    let reactor_addr = tcp::spawn_serve(reactor_sched.clone(), "127.0.0.1:0").unwrap();
    let threaded_addr = tcp::spawn_serve_threaded(threaded_sched.clone(), "127.0.0.1:0").unwrap();

    let req = r#"{"prompt": "", "grammar": "json", "stream": true, "max_tokens": 24, "temperature": 1.0, "seed": 7}"#;
    let (ev_reactor, fin_reactor) = stream_once(reactor_addr, req);
    let (ev_threaded, fin_threaded) = stream_once(threaded_addr, req);

    assert!(!ev_reactor.is_empty(), "expected token events");
    assert_eq!(ev_reactor, ev_threaded, "event lines must be byte-identical");
    for fin in [&fin_reactor, &fin_threaded] {
        assert_eq!(fin.get("error"), Some(&Json::Null));
    }
    for field in ["text", "tokens", "interventions", "model_calls", "stopped"] {
        assert_eq!(
            fin_reactor.get(field),
            fin_threaded.get(field),
            "final `{field}` must match between reactor and threaded paths"
        );
    }
}

/// The soak: many parked keepalive connections stay open and *usable*
/// while decode traffic flows, and both the stats op and the Prometheus
/// exposition agree about the connection count.
#[test]
fn gateway_soaks_idle_connections_with_live_traffic() {
    let sched = Arc::new(mock_sched(1, 2));
    let reactor = Reactor::start(
        &sched,
        Some("127.0.0.1:0"),
        Some("127.0.0.1:0"),
        ReactorConfig::default(),
    )
    .unwrap();
    let jsonl = reactor.jsonl_addr().unwrap();
    let metrics = reactor.metrics_addr().unwrap();
    let stats = reactor.stats();

    const IDLE: usize = 64;
    let idle: Vec<TcpStream> = (0..IDLE).map(|_| TcpStream::connect(jsonl).unwrap()).collect();
    let t0 = Instant::now();
    while stats.open() < IDLE as u64 {
        assert!(t0.elapsed() < Duration::from_secs(10), "accept loop stalled");
        std::thread::sleep(Duration::from_millis(5));
    }

    // Decode through a handful of *parked* connections: they are state
    // machines mid-pool, not sockets in an accept backlog.
    for conn in idle.iter().take(4) {
        let mut w = conn.try_clone().unwrap();
        writeln!(
            w,
            r#"{{"prompt": "", "grammar": "json", "stream": true, "max_tokens": 8, "temperature": 1.0, "seed": 3}}"#
        )
        .unwrap();
        let (events, fin) = collect_stream(BufReader::new(conn.try_clone().unwrap()));
        assert_eq!(fin.get("error"), Some(&Json::Null));
        let mut text = String::new();
        for line in &events {
            text.push_str(Json::parse(line).unwrap().get("token").unwrap().as_str().unwrap());
        }
        assert_eq!(fin.get("text").unwrap().as_str().unwrap(), text);
    }

    // The stats op sees the gateway counters.
    let mut conn = TcpStream::connect(jsonl).unwrap();
    writeln!(conn, r#"{{"op": "stats"}}"#).unwrap();
    let mut line = String::new();
    BufReader::new(conn.try_clone().unwrap()).read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert!(
        v.get("connections_open").unwrap().as_f64().unwrap() >= IDLE as f64,
        "stats op must count the parked connections: {line}"
    );
    assert!(v.get("connections_accepted").unwrap().as_f64().unwrap() >= IDLE as f64);

    // So does the Prometheus exposition.
    let mut scrape = TcpStream::connect(metrics).unwrap();
    scrape.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut body = String::new();
    scrape.read_to_string(&mut body).unwrap();
    assert!(body.starts_with("HTTP/1.1 200"), "{body}");
    let open = body
        .lines()
        .find(|l| l.starts_with("domino_connections_open"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or_else(|| panic!("no domino_connections_open sample in:\n{body}"));
    assert!(open >= IDLE as f64, "exposition disagrees with held connections: {open}");

    drop(idle);
    reactor.stop();
}

/// Accepts beyond `max_connections` get the structured refusal line
/// (JSONL) or a 503 (metrics) and an immediate close.
#[test]
fn over_cap_connections_are_refused_with_structured_reason() {
    let sched = Arc::new(mock_sched(1, 2));
    let cfg = ReactorConfig { max_connections: 2, ..ReactorConfig::default() };
    let reactor = Reactor::start(&sched, Some("127.0.0.1:0"), Some("127.0.0.1:0"), cfg).unwrap();
    let jsonl = reactor.jsonl_addr().unwrap();
    let metrics = reactor.metrics_addr().unwrap();
    let stats = reactor.stats();

    let _held: Vec<TcpStream> = (0..2).map(|_| TcpStream::connect(jsonl).unwrap()).collect();
    let t0 = Instant::now();
    while stats.open() < 2 {
        assert!(t0.elapsed() < Duration::from_secs(10), "accept loop stalled");
        std::thread::sleep(Duration::from_millis(5));
    }

    let over = TcpStream::connect(jsonl).unwrap();
    let mut reader = BufReader::new(over);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert_eq!(v.get("error").unwrap().as_str(), Some("overloaded"), "{line}");
    assert_eq!(v.get("reason").unwrap().as_str(), Some("connection_limit"), "{line}");
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "refused conn must close");

    let mut over_http = TcpStream::connect(metrics).unwrap();
    let mut body = String::new();
    over_http.read_to_string(&mut body).unwrap();
    assert!(body.starts_with("HTTP/1.1 503"), "{body}");
    assert!(stats.rejected() >= 2, "refusals must be counted");
    reactor.stop();
}

/// A silent keepalive connection is closed after the idle timeout with a
/// final structured line.
#[test]
fn idle_timeout_closes_silent_connections() {
    let sched = Arc::new(mock_sched(1, 2));
    let cfg = ReactorConfig {
        idle_timeout: Some(Duration::from_millis(150)),
        ..ReactorConfig::default()
    };
    let reactor = Reactor::start(&sched, Some("127.0.0.1:0"), None, cfg).unwrap();
    let conn = TcpStream::connect(reactor.jsonl_addr().unwrap()).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert_eq!(v.get("error").unwrap().as_str(), Some("timeout"), "{line}");
    assert_eq!(v.get("reason").unwrap().as_str(), Some("idle_timeout"), "{line}");
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "timed-out conn must close");
    reactor.stop();
}

/// A stalled partial request line — the slow-loris shape — is cut by the
/// read timeout on the JSONL listener...
#[test]
fn read_timeout_cuts_stalled_partial_requests() {
    let sched = Arc::new(mock_sched(1, 2));
    let cfg = ReactorConfig {
        idle_timeout: None,
        read_timeout: Some(Duration::from_millis(150)),
        ..ReactorConfig::default()
    };
    let reactor = Reactor::start(&sched, Some("127.0.0.1:0"), None, cfg).unwrap();
    let mut conn = TcpStream::connect(reactor.jsonl_addr().unwrap()).unwrap();
    conn.write_all(br#"{"prompt": "never fini"#).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert_eq!(v.get("error").unwrap().as_str(), Some("timeout"), "{line}");
    assert_eq!(v.get("reason").unwrap().as_str(), Some("read_timeout"), "{line}");
    reactor.stop();
}

/// ...and on the metrics listener, where the pre-reactor implementation
/// would have parked an unnamed thread forever (the `spawn_metrics_http`
/// slow-loris bug this regression test pins). A healthy scrape on the
/// same listener still succeeds first.
#[test]
fn metrics_slow_loris_gets_408_not_a_parked_thread() {
    let sched = Arc::new(mock_sched(1, 2));
    let cfg = ReactorConfig {
        idle_timeout: None,
        read_timeout: Some(Duration::from_millis(150)),
        ..ReactorConfig::default()
    };
    let reactor = Reactor::start(&sched, None, Some("127.0.0.1:0"), cfg).unwrap();
    let metrics = reactor.metrics_addr().unwrap();

    let mut healthy = TcpStream::connect(metrics).unwrap();
    healthy.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut body = String::new();
    healthy.read_to_string(&mut body).unwrap();
    assert!(body.starts_with("HTTP/1.1 200"), "{body}");

    let mut loris = TcpStream::connect(metrics).unwrap();
    loris.write_all(b"GET /metrics HTT").unwrap(); // head never terminates
    loris.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut body = String::new();
    loris.read_to_string(&mut body).unwrap();
    assert!(body.starts_with("HTTP/1.1 408"), "stalled head must get a 408: {body}");
    reactor.stop();
}

/// A *complete* pipelined request parked behind a long-running in-flight
/// one must not trip the read timeout: the timeout clock runs only on a
/// genuinely partial tail frame while no request is in flight, so the
/// buffered follow-up is answered once the first request finishes —
/// even when that takes far longer than `read_timeout`.
#[test]
fn pipelined_request_behind_slow_inflight_survives_read_timeout() {
    let sched = Arc::new(slow_sched(30));
    let cfg = ReactorConfig {
        idle_timeout: None,
        read_timeout: Some(Duration::from_millis(150)),
        ..ReactorConfig::default()
    };
    let reactor = Reactor::start(&sched, Some("127.0.0.1:0"), None, cfg).unwrap();
    let mut conn = TcpStream::connect(reactor.jsonl_addr().unwrap()).unwrap();
    // Both requests in one write: generation (~16 x 30 ms, several times
    // the read timeout) with the stats op pipelined behind it.
    conn.write_all(
        b"{\"prompt\": \"\", \"grammar\": \"json\", \"max_tokens\": 16, \"seed\": 1}\n\
          {\"op\": \"stats\"}\n",
    )
    .unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert_eq!(v.get("error"), Some(&Json::Null), "generation must succeed: {line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert!(
        v.get("requests_completed").is_some(),
        "pipelined stats request must be answered, not timed out: {line}"
    );
    reactor.stop();
}

/// A client that pipelines bytes faster than the gateway parses them
/// (here: unbounded junk behind a slow in-flight generation) gets TCP
/// backpressure, not server memory — the gateway stops reading at its
/// buffer cap and the client's own writes stall.
#[test]
fn pipelined_flood_behind_inflight_is_backpressured() {
    let sched = Arc::new(slow_sched(50));
    let reactor =
        Reactor::start(&sched, Some("127.0.0.1:0"), None, ReactorConfig::default()).unwrap();
    let mut conn = TcpStream::connect(reactor.jsonl_addr().unwrap()).unwrap();
    writeln!(
        conn,
        r#"{{"prompt": "", "grammar": "json", "stream": true, "max_tokens": 48, "temperature": 1.0}}"#
    )
    .unwrap();

    // Flood complete newline-terminated junk lines without ever reading.
    // The parse loop is parked behind the in-flight request, so an
    // unbounded gateway would buffer all of this; the capped one stops
    // reading within a few MiB and the flood hits a sustained WouldBlock.
    conn.set_nonblocking(true).unwrap();
    let mut chunk = vec![b'x'; 8192];
    *chunk.last_mut().unwrap() = b'\n';
    const WRITE_CEILING: usize = 32 << 20;
    let mut total = 0usize;
    let mut stalled_at: Option<Instant> = None;
    let mut sustained = false;
    let deadline = Instant::now() + Duration::from_secs(8);
    while Instant::now() < deadline {
        match conn.write(&chunk) {
            Ok(n) => {
                total += n;
                stalled_at = None;
                assert!(
                    total < WRITE_CEILING,
                    "gateway accepted {total} flood bytes behind an in-flight request — \
                     read_buf is unbounded again"
                );
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if stalled_at.get_or_insert(Instant::now()).elapsed()
                    >= Duration::from_millis(500)
                {
                    sustained = true;
                    break;
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => panic!("flood write failed: {e}"),
        }
    }
    assert!(sustained, "expected sustained backpressure, wrote {total} bytes");
    // Drop the flooding client first so the drain does not wait out its
    // buffered junk: the next streamed event write fails and the
    // connection is reaped as broken.
    drop(conn);
    reactor.stop();
}

/// Newline-terminated HTTP header lines that never finish the head must
/// not accumulate unboundedly on the metrics listener: past the head cap
/// the client gets a 431 and the connection closes (read timeouts
/// disabled here to prove the byte cap acts on its own).
#[test]
fn metrics_unterminated_header_flood_gets_431() {
    let sched = Arc::new(mock_sched(1, 2));
    let cfg = ReactorConfig {
        idle_timeout: None,
        read_timeout: None,
        ..ReactorConfig::default()
    };
    let reactor = Reactor::start(&sched, None, Some("127.0.0.1:0"), cfg).unwrap();
    let mut conn = TcpStream::connect(reactor.metrics_addr().unwrap()).unwrap();
    conn.write_all(b"GET /metrics HTTP/1.1\r\n").unwrap();
    let pad = format!("X-Pad: {}\r\n", "a".repeat(120));
    for _ in 0..256 {
        // 256 x 129 B = 32 KiB of header lines, twice the head cap.
        if conn.write_all(pad.as_bytes()).is_err() {
            break; // server may already have cut us off mid-flood
        }
    }
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut body = String::new();
    let _ = conn.read_to_string(&mut body); // reset after close is fine
    assert!(body.starts_with("HTTP/1.1 431"), "oversized head must get a 431: {body}");
    reactor.stop();
}

/// A peer that requests work, lets its receive window fill, and never
/// reads again is neither idle nor mid-request; the write-stall timeout
/// must cut it instead of letting it park in a connection slot forever.
#[test]
fn write_stalled_peer_is_cut_and_counted() {
    let sched = Arc::new(mock_sched(1, 2));
    let cfg = ReactorConfig {
        idle_timeout: None,
        read_timeout: None,
        write_stall_timeout: Some(Duration::from_millis(150)),
        ..ReactorConfig::default()
    };
    let reactor = Reactor::start(&sched, Some("127.0.0.1:0"), None, cfg).unwrap();
    let jsonl = reactor.jsonl_addr().unwrap();
    let stats = reactor.stats();

    // Measure one reply so the flood can target a total reply volume
    // well past what the kernel socket buffers can absorb (so the write
    // side genuinely stalls) but safely under the 8 MiB write-buffer cap
    // (so the stall timeout, not the cap, is what fires).
    let mut probe = TcpStream::connect(jsonl).unwrap();
    probe.write_all(b"nope\n").unwrap();
    let mut reply = String::new();
    BufReader::new(probe.try_clone().unwrap()).read_line(&mut reply).unwrap();
    assert!(reply.contains("bad request"), "probe expected a parse error: {reply}");
    let n = (6 << 20) / reply.len() + 1;

    let mut glutton = TcpStream::connect(jsonl).unwrap();
    glutton.write_all("nope\n".repeat(n).as_bytes()).unwrap();
    // Never read a byte of the ~6 MiB of replies.
    let t0 = Instant::now();
    while stats.write_stalls() == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(15),
            "write-stalled connection was never cut (write_stalls still 0, open={})",
            stats.open()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    // The slot is actually released, not just counted.
    let t0 = Instant::now();
    while stats.open() > 1 {
        assert!(t0.elapsed() < Duration::from_secs(10), "cut connection still open");
        std::thread::sleep(Duration::from_millis(10));
    }
    drop(glutton);
    reactor.stop();
}

/// A request line that is not valid UTF-8 gets a structured bad-request
/// reply and the connection closes — the gateway matches the threaded
/// path's strictness (which drops such connections) instead of silently
/// mangling bytes with a lossy decode.
#[test]
fn invalid_utf8_request_line_is_rejected_structurally() {
    let sched = Arc::new(mock_sched(1, 2));
    let addr = tcp::spawn_serve(sched.clone(), "127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(b"{\"op\": \"stats\", \"x\": \"\x80\"}\n").unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    let err = v.get("error").unwrap().as_str().unwrap().to_string();
    assert!(err.contains("not valid UTF-8"), "{line}");
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "connection must close after reject");
}

/// Frames split across arbitrary writes reassemble, and the connection
/// stays usable for the next request (keepalive).
#[test]
fn partial_frames_reassemble_and_keepalive_continues() {
    let sched = Arc::new(mock_sched(1, 2));
    let addr = tcp::spawn_serve(sched.clone(), "127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    conn.write_all(br#"{"prompt": "", "gram"#).unwrap();
    conn.flush().unwrap();
    std::thread::sleep(Duration::from_millis(50));
    conn.write_all(b"mar\": \"json\", \"max_tokens\": 8}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(Json::parse(&line).unwrap().get("error"), Some(&Json::Null), "{line}");

    writeln!(conn, r#"{{"op": "stats"}}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(
        Json::parse(&line).unwrap().get("requests_completed").unwrap().as_f64().unwrap() >= 1.0,
        "{line}"
    );
}

/// A client that half-closes after its request still gets the full reply.
#[test]
fn half_close_still_receives_reply() {
    let sched = Arc::new(mock_sched(1, 2));
    let addr = tcp::spawn_serve(sched.clone(), "127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(addr).unwrap();
    writeln!(conn, r#"{{"prompt": "", "grammar": "json", "max_tokens": 8}}"#).unwrap();
    conn.shutdown(Shutdown::Write).unwrap();
    let mut body = String::new();
    BufReader::new(conn).read_to_string(&mut body).unwrap();
    let v = Json::parse(body.lines().next().unwrap()).unwrap();
    assert_eq!(v.get("error"), Some(&Json::Null), "{body}");
}

/// Graceful drain: `Reactor::stop` lets an in-flight stream finish and
/// flush (events, then the final object), then closes the connection.
#[test]
fn drain_finishes_inflight_streams_before_closing() {
    let sched = Arc::new(slow_sched(3));
    let reactor =
        Reactor::start(&sched, Some("127.0.0.1:0"), None, ReactorConfig::default()).unwrap();
    let addr = reactor.jsonl_addr().unwrap();

    let mut conn = TcpStream::connect(addr).unwrap();
    writeln!(
        conn,
        r#"{{"prompt": "", "grammar": "json", "stream": true, "max_tokens": 32, "temperature": 1.0}}"#
    )
    .unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    // Prove decoding started before initiating the drain.
    for _ in 0..2 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(
            Json::parse(&line).unwrap().get("token").is_some(),
            "expected a token event, got {line}"
        );
    }
    let stopper = std::thread::spawn(move || reactor.stop());

    // The rest of the stream must arrive intact, terminated by the final
    // object, then EOF as the drained gateway closes the connection.
    let mut finished = false;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line).unwrap() == 0 {
            break;
        }
        let v = Json::parse(&line).unwrap();
        if v.get("token").is_none() {
            assert_eq!(v.get("error"), Some(&Json::Null), "{line}");
            finished = true;
        }
    }
    assert!(finished, "drain must deliver the final stats object before closing");
    stopper.join().unwrap();
}
