//! Sharded-scheduler integration over the mock LM: cross-shard registry
//! dedup + grammar-affinity routing, work-stealing spill, queue-overflow
//! shedding, per-request deadlines, cancellation (in-process and via TCP
//! disconnect), streaming, tail-captured traces for aborted streams, and
//! the stats op.

use domino::constraint::{Constraint, ConstraintSpec};
use domino::runtime::mock::{json_mock, MockFactory};
use domino::runtime::{LmFactory, LmSession};
use domino::server::engine::{EngineCtx, GenRequest};
use domino::server::scheduler::{Scheduler, SchedulerConfig};
use domino::server::tcp;
use domino::server::trace::{CaptureCause, TraceConfig};
use domino::util::Json;
use domino::TokenId;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn cfg(engines: usize, slots: usize, depth: usize) -> SchedulerConfig {
    SchedulerConfig {
        engines,
        slots_per_engine: slots,
        queue_depth: depth,
        ..SchedulerConfig::default()
    }
}

/// Mock-LM scheduler; one vocab Arc shared across shards (registry keys
/// hash the vocab content, so equal copies would dedupe too).
fn mock_sched(engines: usize, slots: usize, depth: usize) -> Scheduler {
    let (vocab, model) = json_mock(512);
    Scheduler::start(
        move |_shard, registry| {
            Ok(EngineCtx::with_registry(
                Box::new(MockFactory { model: model.clone() }),
                vocab.clone(),
                registry,
            ))
        },
        cfg(engines, slots, depth),
    )
}

/// An LM whose every forward pass takes `delay` — makes decodes slow
/// enough to observe queues, cancellation and deadlines mid-flight.
struct SlowFactory {
    inner: MockFactory,
    delay: Duration,
}

struct SlowSession {
    inner: Box<dyn LmSession>,
    delay: Duration,
}

impl LmFactory for SlowFactory {
    fn vocab_size(&self) -> usize {
        self.inner.vocab_size()
    }

    fn new_session(&self) -> domino::Result<Box<dyn LmSession>> {
        Ok(Box::new(SlowSession { inner: self.inner.new_session()?, delay: self.delay }))
    }
}

impl LmSession for SlowSession {
    fn vocab_size(&self) -> usize {
        self.inner.vocab_size()
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn append(&mut self, tokens: &[TokenId]) -> domino::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        self.inner.append(tokens)
    }

    fn append_scored(&mut self, tokens: &[TokenId]) -> domino::Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.delay);
        self.inner.append_scored(tokens)
    }

    fn rollback(&mut self, n: usize) -> domino::Result<()> {
        self.inner.rollback(n)
    }
}

fn slow_sched(engines: usize, slots: usize, depth: usize, delay_ms: u64) -> Scheduler {
    let (vocab, model) = json_mock(512);
    Scheduler::start(
        move |_shard, registry| {
            Ok(EngineCtx::with_registry(
                Box::new(SlowFactory {
                    inner: MockFactory { model: model.clone() },
                    delay: Duration::from_millis(delay_ms),
                }),
                vocab.clone(),
                registry,
            ))
        },
        cfg(engines, slots, depth),
    )
}

/// An LM that errors after `fail_after` forward passes — exercises the
/// mid-step slot-error path.
struct FailingFactory {
    inner: MockFactory,
    fail_after: usize,
}

struct FailingSession {
    inner: Box<dyn LmSession>,
    calls: usize,
    fail_after: usize,
}

impl LmFactory for FailingFactory {
    fn vocab_size(&self) -> usize {
        self.inner.vocab_size()
    }

    fn new_session(&self) -> domino::Result<Box<dyn LmSession>> {
        Ok(Box::new(FailingSession {
            inner: self.inner.new_session()?,
            calls: 0,
            fail_after: self.fail_after,
        }))
    }
}

impl LmSession for FailingSession {
    fn vocab_size(&self) -> usize {
        self.inner.vocab_size()
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn append(&mut self, tokens: &[TokenId]) -> domino::Result<Vec<f32>> {
        self.calls += 1;
        anyhow::ensure!(self.calls <= self.fail_after, "injected model failure");
        self.inner.append(tokens)
    }

    fn append_scored(&mut self, tokens: &[TokenId]) -> domino::Result<Vec<Vec<f32>>> {
        self.calls += 1;
        anyhow::ensure!(self.calls <= self.fail_after, "injected model failure");
        self.inner.append_scored(tokens)
    }

    fn rollback(&mut self, n: usize) -> domino::Result<()> {
        self.inner.rollback(n)
    }
}

fn req(grammar: &str, max_tokens: usize, seed: u64) -> GenRequest {
    GenRequest {
        prompt: String::new(),
        constraint: Constraint::domino(ConstraintSpec::builtin(grammar)),
        max_tokens,
        temperature: Some(1.0),
        seed,
        ..Default::default()
    }
}

#[test]
fn shards_share_one_registry_compile_per_grammar() {
    let sched = mock_sched(4, 2, 64);
    let grammars = ["json", "gsm8k", "c"];
    let handles: Vec<_> =
        (0..12).map(|i| sched.submit(req(grammars[i % 3], 12, i as u64))).collect();
    for h in handles {
        let r = h.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    let m = sched.metrics().unwrap();
    assert_eq!(m.requests_completed, 12);
    assert_eq!(
        m.registry_misses, 3,
        "one shared compile per distinct grammar across all shards: {m:?}"
    );
    assert_eq!(m.registry_hits, 9, "warm shards must reuse the shared engines");

    // Affinity: each grammar hashes to one preferred shard, and nothing
    // spilled (queues far under depth) — so at most 3 shards saw work.
    let shards = sched.shard_metrics().unwrap();
    let used = shards.iter().filter(|s| s.requests_completed > 0).count();
    assert!(used <= 3, "affinity routing must not scatter 3 grammars over {used} shards");
    sched.shutdown();
}

#[test]
fn affinity_pins_one_grammar_to_one_shard() {
    let sched = mock_sched(4, 2, 64);
    let handles: Vec<_> = (0..8).map(|i| sched.submit(req("json", 8, i as u64))).collect();
    for h in handles {
        assert!(h.recv().unwrap().error.is_none());
    }
    let shards = sched.shard_metrics().unwrap();
    let used = shards.iter().filter(|s| s.requests_completed > 0).count();
    assert_eq!(used, 1, "one grammar under light load must stay on its preferred shard");
    sched.shutdown();
}

#[test]
fn full_preferred_shard_spills_to_least_loaded() {
    // Shard count 2, one slot and queue depth 2 per shard, slow decodes.
    let sched = slow_sched(2, 1, 2, 5);
    let preferred = (ConstraintSpec::builtin("json").fingerprint() % 2) as usize;
    // Occupy the preferred shard's slot with a long request...
    let long = sched.submit(req("json", 100, 0));
    std::thread::sleep(Duration::from_millis(60)); // until it is admitted
    // ...then fill its queue (depth 2) and two more that must spill.
    let fillers: Vec<_> = (0..4).map(|i| sched.submit(req("json", 2, i + 1))).collect();
    for f in fillers {
        let r = f.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    let shards = sched.shard_metrics().unwrap();
    assert!(
        shards[1 - preferred].requests_completed >= 1,
        "overflow past the preferred queue must spill to the other shard: {shards:?}"
    );
    let r = long.recv().unwrap();
    assert!(r.error.is_none());
    sched.shutdown();
}

#[test]
fn admission_error_reports_to_client() {
    let sched = mock_sched(2, 2, 16);
    let r = sched.generate(req("no-such-grammar", 8, 0)).unwrap();
    assert!(r.error.is_some(), "unknown grammar must fail the request");
    let m = sched.metrics().unwrap();
    assert_eq!(m.requests_failed, 1);
    assert_eq!(m.requests_completed, 0);
    sched.shutdown();
}

#[test]
fn mid_step_slot_error_fails_request_not_engine() {
    let (vocab, model) = json_mock(512);
    let sched = Scheduler::start(
        move |_shard, registry| {
            let factory = Box::new(FailingFactory {
                inner: MockFactory { model: model.clone() },
                fail_after: 3,
            });
            Ok(EngineCtx::with_registry(factory, vocab.clone(), registry))
        },
        cfg(1, 2, 16),
    );
    let r = sched
        .generate(GenRequest { max_tokens: 32, ..Default::default() })
        .unwrap();
    assert!(r.error.as_deref().unwrap_or("").contains("injected model failure"), "{:?}", r.error);
    assert!(r.stats.tokens_out < 32, "the slot must die mid-decode");
    // The engine survives: a session that doesn't hit the injected limit
    // still completes.
    let r2 = sched.generate(GenRequest { max_tokens: 1, ..Default::default() }).unwrap();
    assert!(r2.error.is_none(), "{:?}", r2.error);
    let m = sched.metrics().unwrap();
    assert_eq!(m.requests_failed, 1);
    assert_eq!(m.requests_completed, 1);
    sched.shutdown();
}

#[test]
fn queue_overflow_sheds_with_structured_error() {
    let sched = slow_sched(1, 1, 1, 10);
    let handles: Vec<_> = (0..6).map(|i| sched.submit(req("json", 16, i))).collect();
    let mut ok = 0;
    let mut shed = 0;
    for h in handles {
        let r = h.recv().unwrap();
        match r.error.as_deref() {
            None => ok += 1,
            Some("overloaded") => shed += 1,
            Some(other) => panic!("unexpected error: {other}"),
        }
    }
    assert!(ok >= 1, "at least the first request must be served");
    assert!(shed >= 1, "a bounded queue must shed under burst load");
    let m = sched.metrics().unwrap();
    assert_eq!(m.requests_shed, shed as u64);
    assert_eq!(m.requests_completed, ok as u64);
    sched.shutdown();
}

#[test]
fn cancellation_aborts_mid_decode() {
    let sched = slow_sched(1, 1, 4, 5);
    let handle = sched.submit(req("json", 400, 0));
    std::thread::sleep(Duration::from_millis(60));
    handle.cancel();
    let r = handle.recv().unwrap();
    assert_eq!(r.error.as_deref(), Some("cancelled"));
    assert!(
        r.stats.tokens_out < 400,
        "the slot must abort well before max_tokens, got {}",
        r.stats.tokens_out
    );
    let m = sched.metrics().unwrap();
    assert_eq!(m.requests_cancelled, 1);
    assert_eq!(m.requests_completed, 0);
    sched.shutdown();
}

#[test]
fn cancelled_stream_flushes_tail_trace_before_reap() {
    // Tail-based capture only (head sampling off, slow bar unreachable):
    // a cancelled streaming request must still land its trace in the
    // ring — flushed with the abort, before the slot is reaped.
    let (vocab, model) = json_mock(512);
    let mut config = cfg(1, 1, 4);
    config.trace = TraceConfig { slow: Some(Duration::from_secs(3600)), ..TraceConfig::default() };
    let sched = Scheduler::start(
        move |_shard, registry| {
            Ok(EngineCtx::with_registry(
                Box::new(SlowFactory {
                    inner: MockFactory { model: model.clone() },
                    delay: Duration::from_millis(5),
                }),
                vocab.clone(),
                registry,
            ))
        },
        config,
    );
    let (stx, srx) = mpsc::channel();
    let handle = sched.submit_streaming(req("json", 400, 0), stx);
    // Wait for a streamed token so the abort lands mid-decode.
    let first = srx.recv_timeout(Duration::from_secs(10)).expect("decode must start");
    assert_eq!(first.index, 1);
    handle.cancel();
    let r = handle.recv().unwrap();
    assert_eq!(r.error.as_deref(), Some("cancelled"));

    // The final response is sent after the trace flush, so by now the
    // ring must hold the tail-captured trace with its abort reason and
    // the decisions recorded up to the cancel.
    let recent = sched.tracer().recent();
    assert_eq!(recent.len(), 1, "aborted stream must be tail-captured");
    let t = &recent[0];
    assert_eq!(t.cause, CaptureCause::Aborted);
    assert_eq!(t.abort.as_deref(), Some("client_cancel"));
    assert!(t.ticks >= 1, "the trace must cover the ticks before the abort");
    assert!(!t.decisions.is_empty(), "streamed tokens must have decision records");
    assert!(t.decisions.len() < 400, "the trace ends at the abort, not max_tokens");
    assert!(t.spans.iter().any(|s| s.name == "decode"), "decode span closed by the flush");
    sched.shutdown();
}

#[test]
fn deadline_aborts_queued_and_running_work() {
    let sched = slow_sched(1, 1, 8, 5);
    // Running request: deadline fires mid-decode.
    let mut running = req("json", 400, 0);
    running.deadline = Some(Duration::from_millis(100));
    // Queued request: sits behind the first, deadline fires in queue.
    let mut queued = req("json", 4, 1);
    queued.deadline = Some(Duration::from_millis(30));
    let h1 = sched.submit(running);
    let h2 = sched.submit(queued);
    let r1 = h1.recv().unwrap();
    assert_eq!(r1.error.as_deref(), Some("deadline exceeded"));
    assert!(r1.stats.tokens_out < 400);
    let r2 = h2.recv().unwrap();
    assert_eq!(r2.error.as_deref(), Some("deadline exceeded"));
    assert_eq!(r2.stats.tokens_out, 0, "queued request must die before admission");
    let m = sched.metrics().unwrap();
    assert_eq!(m.requests_deadline_exceeded, 2);
    sched.shutdown();
}

#[test]
fn streaming_events_concatenate_to_final_text() {
    let sched = mock_sched(1, 2, 16);
    let (stx, srx) = mpsc::channel();
    let handle = sched.submit_streaming(req("json", 32, 7), stx);
    let mut streamed = String::new();
    let mut count = 0usize;
    for ev in srx.iter() {
        count += 1;
        assert_eq!(ev.index, count, "events must arrive in order");
        streamed.push_str(&ev.text);
    }
    let r = handle.recv().unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(streamed, r.text, "stream concatenation must equal the final text");
    assert_eq!(count, r.stats.tokens_out, "one event per committed token");
    sched.shutdown();
}

#[test]
fn tcp_stream_disconnect_cancels_slot() {
    let sched = Arc::new(slow_sched(1, 1, 8, 5));
    let addr = tcp::spawn_serve(sched.clone(), "127.0.0.1:0").unwrap();
    {
        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(
            conn,
            r#"{{"prompt": "", "grammar": "json", "stream": true, "max_tokens": 400, "temperature": 1.0}}"#
        )
        .unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        // Read a couple of token events to prove decoding started...
        for _ in 0..2 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let v = Json::parse(&line).unwrap();
            assert!(v.get("token").is_some(), "expected a token event, got {line}");
        }
        // ...then hang up mid-stream.
    }
    let t0 = Instant::now();
    loop {
        let m = sched.metrics().unwrap();
        if m.requests_cancelled >= 1 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "disconnect must cancel the in-flight slot: {m:?}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn tcp_stats_op_returns_cross_shard_snapshot() {
    let sched = Arc::new(mock_sched(2, 2, 16));
    let addr = tcp::spawn_serve(sched.clone(), "127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(addr).unwrap();
    writeln!(conn, r#"{{"prompt": "", "grammar": "json", "max_tokens": 8}}"#).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert_eq!(v.get("error"), Some(&Json::Null), "{line}");

    writeln!(conn, r#"{{"op": "stats"}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert_eq!(v.get("engines").unwrap().as_f64().unwrap(), 2.0);
    assert!(v.get("requests_completed").unwrap().as_f64().unwrap() >= 1.0, "{line}");
    assert!(v.get("registry_misses").unwrap().as_f64().unwrap() >= 1.0, "{line}");
}

#[test]
fn streaming_over_tcp_terminates_with_stats_object() {
    let sched = Arc::new(mock_sched(1, 2, 16));
    let addr = tcp::spawn_serve(sched.clone(), "127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(addr).unwrap();
    writeln!(
        conn,
        r#"{{"prompt": "", "grammar": "json", "stream": true, "max_tokens": 16, "temperature": 1.0}}"#
    )
    .unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    let mut streamed = String::new();
    let mut finished = false;
    for line in reader.lines() {
        let line = line.unwrap();
        let v = Json::parse(&line).unwrap();
        if let Some(tok) = v.get("token") {
            streamed.push_str(tok.as_str().unwrap());
        } else {
            // The final stats object ends the stream.
            assert_eq!(v.get("error"), Some(&Json::Null), "{line}");
            assert_eq!(v.get("text").unwrap().as_str().unwrap(), streamed, "{line}");
            finished = true;
            break;
        }
    }
    assert!(finished, "stream must terminate with the final stats object");
}
