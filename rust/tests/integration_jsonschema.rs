//! JSON-Schema constraint integration: schema source → `grammar::jsonschema`
//! compiler → `EngineRegistry` → serving engine → TCP wire, over the mock
//! LM.
//!
//! Covers the tentpole acceptance criteria end to end:
//! * a function-calling schema submitted **over the wire** produces
//!   output that parses as JSON and validates against the schema (via
//!   the small subset validator below);
//! * the same schema — spelled with different key order / whitespace /
//!   field form — compiles **once** in the registry;
//! * a schema engine round-trips through the `ArtifactStore` across a
//!   kill-and-restart;
//! * unsupported keywords fail with a path-annotated error (no
//!   silently-unconstrained fallback), surfaced through the wire too;
//! * conflicting wire constraint fields are rejected with a structured
//!   error.

use domino::constraint::{ArtifactStore, Constraint, ConstraintSpec, EngineRegistry};
use domino::eval::workload::FUNCTION_CALL_SCHEMA;
use domino::runtime::mock::{json_mock, MockFactory};
use domino::server::engine::{EngineCtx, GenRequest, Server};
use domino::server::scheduler::{Scheduler, SchedulerConfig};
use domino::server::tcp;
use domino::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// A small JSON-Schema validator for the compiled subset — the test-side
// oracle that generated output actually satisfies the schema (independent
// of the grammar that constrained it).
// ---------------------------------------------------------------------------

fn validate(root: &Json, schema: &Json, value: &Json, path: &str) -> Result<(), String> {
    match schema {
        Json::Bool(true) => Ok(()),
        Json::Bool(false) => Err(format!("{path}: `false` schema")),
        Json::Obj(m) => {
            if let Some(r) = m.get("$ref").and_then(|r| r.as_str()) {
                let mut target = root;
                for seg in r.trim_start_matches('#').split('/').filter(|s| !s.is_empty()) {
                    let seg = seg.replace("~1", "/").replace("~0", "~");
                    target = target.get(&seg).ok_or_else(|| format!("{path}: bad $ref {r}"))?;
                }
                return validate(root, target, value, path);
            }
            if let Some(c) = m.get("const") {
                return if value == c { Ok(()) } else { Err(format!("{path}: != const")) };
            }
            if let Some(Json::Arr(options)) = m.get("enum") {
                return if options.contains(value) {
                    Ok(())
                } else {
                    Err(format!("{path}: not in enum"))
                };
            }
            for comb in ["anyOf", "oneOf"] {
                if let Some(Json::Arr(branches)) = m.get(comb) {
                    let ok = branches
                        .iter()
                        .filter(|b| validate(root, b, value, path).is_ok())
                        .count();
                    return match (comb, ok) {
                        ("anyOf", n) if n >= 1 => Ok(()),
                        ("oneOf", 1) => Ok(()),
                        _ => Err(format!("{path}: {comb} matched {ok} branches")),
                    };
                }
            }
            let types: Vec<String> = match m.get("type") {
                Some(Json::Str(s)) => vec![s.clone()],
                Some(Json::Arr(a)) => {
                    a.iter().filter_map(|t| t.as_str().map(|s| s.to_string())).collect()
                }
                _ => vec![],
            };
            let matches_type = |t: &str| match (t, value) {
                ("null", Json::Null)
                | ("boolean", Json::Bool(_))
                | ("number", Json::Num(_))
                | ("string", Json::Str(_))
                | ("array", Json::Arr(_))
                | ("object", Json::Obj(_)) => true,
                ("integer", Json::Num(n)) => n.fract() == 0.0,
                _ => false,
            };
            if !types.is_empty() && !types.iter().any(|t| matches_type(t)) {
                return Err(format!("{path}: type mismatch"));
            }
            match value {
                Json::Num(n) => {
                    if let Some(lo) = m.get("minimum").and_then(|x| x.as_f64()) {
                        if *n < lo {
                            return Err(format!("{path}: {n} < minimum {lo}"));
                        }
                    }
                    if let Some(hi) = m.get("maximum").and_then(|x| x.as_f64()) {
                        if *n > hi {
                            return Err(format!("{path}: {n} > maximum {hi}"));
                        }
                    }
                }
                Json::Str(s) => {
                    if let Some(p) = m.get("pattern").and_then(|x| x.as_str()) {
                        if !domino::regex::matches(p, s).map_err(|e| format!("{path}: {e}"))? {
                            return Err(format!("{path}: pattern mismatch"));
                        }
                    }
                }
                Json::Obj(fields) => {
                    if let Some(Json::Arr(req)) = m.get("required") {
                        for r in req {
                            let name = r.as_str().unwrap_or_default();
                            if !fields.contains_key(name) {
                                return Err(format!("{path}: missing required `{name}`"));
                            }
                        }
                    }
                    let props = m.get("properties");
                    if let Some(Json::Obj(props)) = props {
                        for (name, sub) in fields {
                            match props.get(name) {
                                Some(ps) => {
                                    validate(root, ps, sub, &format!("{path}/{name}"))?
                                }
                                None => {
                                    if m.get("additionalProperties") == Some(&Json::Bool(false)) {
                                        return Err(format!("{path}: extra property `{name}`"));
                                    }
                                }
                            }
                        }
                    }
                }
                Json::Arr(items) => {
                    if let Some(lo) = m.get("minItems").and_then(|x| x.as_f64()) {
                        if (items.len() as f64) < lo {
                            return Err(format!("{path}: fewer than {lo} items"));
                        }
                    }
                    if let Some(hi) = m.get("maxItems").and_then(|x| x.as_f64()) {
                        if (items.len() as f64) > hi {
                            return Err(format!("{path}: more than {hi} items"));
                        }
                    }
                    if let Some(iv) = m.get("items") {
                        for (i, item) in items.iter().enumerate() {
                            validate(root, iv, item, &format!("{path}/{i}"))?;
                        }
                    }
                }
                _ => {}
            }
            Ok(())
        }
        _ => Err(format!("{path}: schema is not an object or boolean")),
    }
}

fn validate_source(schema_src: &str, value: &Json) -> Result<(), String> {
    let schema = Json::parse(schema_src).map_err(|e| format!("schema: {e:#}"))?;
    validate(&schema, &schema, value, "$")
}

#[test]
fn validator_accepts_and_rejects_by_hand() {
    let ok = Json::parse(
        r#"{"name": "get_weather", "arguments": {"city": "Oslo", "units": "celsius", "days": 3}}"#,
    )
    .unwrap();
    validate_source(FUNCTION_CALL_SCHEMA, &ok).unwrap();
    for bad in [
        r#"{"arguments": {"city": "Oslo", "units": "celsius"}}"#, // name missing
        r#"{"name": "nuke", "arguments": {"city": "x", "units": "celsius"}}"#, // not in enum
        r#"{"name": "get_weather", "arguments": {"city": "x", "units": "celsius", "days": 10}}"#, // > maximum
        r#"{"name": "get_weather", "arguments": {"city": "x", "units": "celsius"}, "extra": 1}"#, // additional
    ] {
        let v = Json::parse(bad).unwrap();
        assert!(validate_source(FUNCTION_CALL_SCHEMA, &v).is_err(), "{bad}");
    }
}

// ---------------------------------------------------------------------------
// Serving-stack integration.
// ---------------------------------------------------------------------------

fn mock_sched(engines: usize) -> Scheduler {
    let (vocab, model) = json_mock(512);
    Scheduler::start(
        move |_shard, registry| {
            Ok(EngineCtx::with_registry(
                Box::new(MockFactory { model: model.clone() }),
                vocab.clone(),
                registry,
            ))
        },
        SchedulerConfig { engines, slots_per_engine: 2, queue_depth: 32, ..Default::default() },
    )
}

/// Send one JSONL request line, read one reply line.
fn roundtrip(conn: &mut TcpStream, reader: &mut impl BufRead, line: &str) -> Json {
    writeln!(conn, "{line}").unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    Json::parse(&reply).unwrap_or_else(|e| panic!("{e:#}: {reply}"))
}

#[test]
fn wire_schema_request_validates_and_compiles_once() {
    let sched = Arc::new(mock_sched(1));
    let addr = tcp::spawn_serve(sched.clone(), "127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    // Inline schema object, canonical-ish spelling.
    let schema_field = FUNCTION_CALL_SCHEMA.replace('\n', " ");
    let req = format!(
        r#"{{"prompt": "A tool call encoded as a JSON object:\n", "json_schema": {schema_field}, "max_tokens": 256}}"#
    );
    let v = roundtrip(&mut conn, &mut reader, &req);
    assert_eq!(v.get("error"), Some(&Json::Null), "{v:?}");
    assert_eq!(v.get("stopped"), Some(&Json::Bool(true)), "schema decode must complete: {v:?}");
    let text = v.get("text").unwrap().as_str().unwrap();
    let parsed = Json::parse(text.trim()).unwrap_or_else(|e| panic!("{e:#}: {text}"));
    validate_source(FUNCTION_CALL_SCHEMA, &parsed)
        .unwrap_or_else(|e| panic!("schema violation {e}: {text}"));

    // The same schema as a string source with scrambled key order — the
    // canonical fingerprint must hit the registry, not recompile.
    let reordered = Json::parse(FUNCTION_CALL_SCHEMA).unwrap().to_string();
    let escaped = Json::str(reordered).to_string();
    let req2 = format!(r#"{{"prompt": "", "json_schema": {escaped}, "max_tokens": 64}}"#);
    let v = roundtrip(&mut conn, &mut reader, &req2);
    assert_eq!(v.get("error"), Some(&Json::Null), "{v:?}");

    let stats = roundtrip(&mut conn, &mut reader, r#"{"op": "stats"}"#);
    assert_eq!(
        stats.get("registry_misses").unwrap().as_f64().unwrap(),
        1.0,
        "one compile for both spellings: {stats:?}"
    );
    assert!(stats.get("registry_hits").unwrap().as_f64().unwrap() >= 1.0, "{stats:?}");
}

#[test]
fn wire_unsupported_keyword_is_path_annotated_and_conflicts_are_rejected() {
    let sched = Arc::new(mock_sched(1));
    let addr = tcp::spawn_serve(sched.clone(), "127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    // Unsupported keyword: the request fails loudly — the server never
    // quietly drops `patternProperties` and serves a weaker constraint.
    let v = roundtrip(
        &mut conn,
        &mut reader,
        r#"{"prompt": "", "json_schema": {"type": "object", "patternProperties": {"^x": {}}}, "max_tokens": 8}"#,
    );
    let err = v.get("error").unwrap().as_str().unwrap_or_default().to_string();
    assert!(err.contains("#/patternProperties"), "{v:?}");
    assert!(err.contains("unsupported keyword"), "{v:?}");

    // Conflicting constraint fields: structured bad request.
    let v = roundtrip(
        &mut conn,
        &mut reader,
        r#"{"prompt": "", "json_schema": {}, "grammar": "json"}"#,
    );
    let err = v.get("error").unwrap().as_str().unwrap_or_default().to_string();
    assert!(err.contains("conflicting constraint fields"), "{v:?}");

    // Unknown builtin names list the known grammars on the wire.
    let v = roundtrip(&mut conn, &mut reader, r#"{"prompt": "", "grammar": "jsonx"}"#);
    let err = v.get("error").unwrap().as_str().unwrap_or_default().to_string();
    assert!(err.contains("unknown builtin grammar"), "{v:?}");
    assert!(err.contains("gsm8k"), "{v:?}");
}

/// A single-shard server whose registry persists to `dir`.
fn server_with_artifacts(dir: std::path::PathBuf) -> Server {
    Server::start(
        move || {
            let (vocab, model) = json_mock(512);
            let registry = EngineRegistry::with_store(8, ArtifactStore::new(dir)?);
            Ok(EngineCtx::with_registry(Box::new(MockFactory { model }), vocab, registry))
        },
        2,
    )
}

#[test]
fn schema_engine_round_trips_through_the_artifact_store() {
    let dir = std::env::temp_dir().join(format!("domino_schema_artifacts_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let req = GenRequest {
        prompt: String::new(),
        constraint: Constraint::domino(ConstraintSpec::json_schema(FUNCTION_CALL_SCHEMA)),
        max_tokens: 48,
        ..Default::default()
    };

    // First life: compile + write-back.
    let server = server_with_artifacts(dir.clone());
    let r = server.generate(req.clone()).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    let m = server.metrics().unwrap();
    assert_eq!(m.registry_misses, 1, "cold boot compiles the schema once");
    server.shutdown();

    // Second life: the warm-start scan restores the schema engine; the
    // first request recompiles nothing.
    let server = server_with_artifacts(dir.clone());
    let r = server.generate(req).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    let m = server.metrics().unwrap();
    assert!(m.artifact_hits >= 1, "restart must boot from the artifact: {m:?}");
    assert_eq!(m.registry_misses, 0, "no recompile after restart: {m:?}");
    assert_eq!(m.engine_compile_ms, 0, "zero compile latency after restart: {m:?}");
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn schema_output_validates_with_recursion_and_unions() {
    // A harder schema: $ref recursion, anyOf, bounded arrays, pattern.
    let schema = r#"{
        "$ref": "#/$defs/node",
        "$defs": {
            "node": {
                "type": "object",
                "additionalProperties": false,
                "required": ["tag"],
                "properties": {
                    "tag": {"type": "string", "pattern": "[a-z]{1,6}"},
                    "value": {"anyOf": [{"type": "integer", "minimum": 0, "maximum": 99}, {"type": "null"}]},
                    "children": {"type": "array", "items": {"$ref": "#/$defs/node"}, "maxItems": 3}
                }
            }
        }
    }"#;
    let server = Server::start(
        move || {
            let (vocab, model) = json_mock(512);
            Ok(EngineCtx::new(Box::new(MockFactory { model }), vocab))
        },
        1,
    );
    let r = server
        .generate(GenRequest {
            prompt: String::new(),
            constraint: Constraint::domino(ConstraintSpec::json_schema(schema)),
            max_tokens: 256,
            ..Default::default()
        })
        .unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    if r.stats.stopped {
        let parsed = Json::parse(r.text.trim()).unwrap_or_else(|e| panic!("{e:#}: {}", r.text));
        validate_source(schema, &parsed)
            .unwrap_or_else(|e| panic!("schema violation {e}: {}", r.text));
    }
    server.shutdown();
}
