//! Property-based invariants (seeded randomized tests via `util::prop`).
//!
//! The load-bearing ones:
//! 1. **Soundness**: any token sequence the DOMINO mask admits decodes to
//!    a viable prefix of the grammar language; EOS only at complete
//!    parses.
//! 2. **Mask agreement**: `check_token(t) ⇔ compute_mask().allowed(t)`
//!    for every token, state and lookahead.
//! 3. **Online ⇔ DOMINO(k=∞) equivalence** along random legal walks.
//! 4. **Scanner/regex agreement**: the scanner accepts exactly the
//!    terminal decompositions the per-terminal DFAs accept.
//! 5. **BPE round-trip** on arbitrary byte strings.
//! 6. **Schema fingerprint normalization**: semantically identical JSON
//!    Schemas (shuffled key order, random whitespace) produce identical
//!    `ConstraintSpec` fingerprints and build fingerprints, so
//!    registry/artifact dedup actually fires for schema constraints.
//! 7. **Wordwise kernel parity**: the word-parallel `TokenMask` sweeps
//!    (`apply`/`intersect`/`and_not`/`iter`) are bit-identical to scalar
//!    references at word-edge sizes, and the sharded mask cache loses no
//!    updates under concurrent mixed load.
//! 8. **Drafted ≡ plain ≡ speculative decoding**: the grammar-pruned
//!    draft lane is acceptance-or-correction over the model's own
//!    choices, so committed token streams are identical under any seed,
//!    grammar, draft depth, prune ordering and sampling mode.

use domino::baselines::OnlineChecker;
use domino::constraint::ConstraintSpec;
use domino::domino::decoder::{Engine, Lookahead};
use domino::domino::{Checker, DominoDecoder};
use domino::grammar::builtin;
use domino::tokenizer::{self, Vocab, EOS_ID};
use domino::util::prop::check;
use domino::util::{Json, Rng};
use std::sync::Arc;

fn test_vocab() -> Arc<Vocab> {
    Arc::new(tokenizer::bpe::synthetic_json_vocab(400))
}

/// Take a random legal walk of up to `steps` mask-sampled tokens.
fn random_walk(dec: &mut DominoDecoder, rng: &mut Rng, steps: usize) -> Vec<domino::TokenId> {
    let mut out = Vec::new();
    for _ in 0..steps {
        let mask = dec.compute_mask();
        let allowed: Vec<_> = mask.iter().collect();
        if allowed.is_empty() {
            break;
        }
        let t = *rng.choose(&allowed);
        if t == EOS_ID {
            break;
        }
        dec.advance(t).unwrap();
        out.push(t);
    }
    out
}

#[test]
fn prop_masked_walks_stay_grammatical() {
    let vocab = test_vocab();
    let engine = Engine::compile(builtin::json(), vocab.clone()).unwrap();
    check("masked-walks-grammatical", 25, |rng| {
        let mut dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        let out = random_walk(&mut dec, rng, 40);
        let text = engine.vocab.decode_str(&out);
        // Either the decoder is still alive (viable prefix) …
        assert!(dec.alive(), "dead decoder after {text:?}");
        // … and if EOS is legal, the text must parse as JSON.
        if dec.check_token(EOS_ID) {
            Json::parse(&text).unwrap_or_else(|e| panic!("{e:#}: {text}"));
        }
    });
}

#[test]
fn prop_check_token_matches_mask() {
    let vocab = test_vocab();
    let engine = Engine::compile(builtin::fig3_expr(), vocab.clone()).unwrap();
    check("check-token-matches-mask", 15, |rng| {
        let k = match rng.below(3) {
            0 => Lookahead::K(0),
            1 => Lookahead::K(1),
            _ => Lookahead::Infinite,
        };
        let mut dec = DominoDecoder::new(engine.clone(), k);
        let steps = rng.below(12);
        let _ = random_walk(&mut dec, rng, steps);
        let mask = dec.compute_mask();
        for id in 0..engine.vocab.len() as domino::TokenId {
            assert_eq!(
                dec.check_token(id),
                mask.allowed(id),
                "token {:?} under {k:?}",
                engine.vocab.token_str(id)
            );
        }
    });
}

#[test]
fn prop_online_equals_domino_infinite() {
    let vocab = test_vocab();
    let engine = Engine::compile(builtin::gsm8k_schema(), vocab.clone()).unwrap();
    check("online-eq-domino", 10, |rng| {
        let mut dom = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        let mut online = OnlineChecker::new(engine.clone());
        for _ in 0..15 {
            let m1 = dom.compute_mask();
            let m2 = online.compute_mask();
            assert_eq!(m1, m2);
            let allowed: Vec<_> = m1.iter().filter(|&t| t != EOS_ID).collect();
            if allowed.is_empty() {
                break;
            }
            let t = *rng.choose(&allowed);
            dom.advance(t).unwrap();
            online.advance(t).unwrap();
        }
    });
}

#[test]
fn prop_drafted_decode_token_identical() {
    // The draft lane is acceptance-or-correction over the model's own
    // choices: under any seed, grammar, draft depth, prune ordering and
    // sampling mode, drafted output must be byte-identical to plain
    // decoding AND to undrafted speculative decoding of the same seed.
    use domino::domino::generate::Prompt;
    use domino::domino::{
        generate, generate_drafted, generate_speculative, GenConfig, MaskMode, SpeculativeModel,
    };
    use domino::runtime::mock::{json_mock, MockLm};
    use domino::runtime::sampler::Sampling;

    let (vocab, model) = json_mock(512);
    let engines = [
        Engine::compile(builtin::gsm8k_schema(), vocab.clone()).unwrap(),
        Engine::compile(builtin::json(), vocab.clone()).unwrap(),
        Engine::compile(builtin::fig3_expr(), vocab.clone()).unwrap(),
    ];
    check("drafted-token-identical", 10, |rng| {
        let engine = engines[rng.below(engines.len())].clone();
        let seed = rng.below(1 << 20) as u64;
        let k_max = 1 + rng.below(8);
        let prune = rng.chance(0.5);
        let sampling =
            if rng.chance(0.5) { Sampling::Greedy } else { Sampling::Temperature(1.0) };
        let cfg = GenConfig { max_tokens: 40, sampling, mode: MaskMode::Opportunistic };
        let prompt = Prompt::default();
        let ctx = format!("seed={seed} k_max={k_max} prune={prune} sampling={sampling:?}");

        let mut lm = MockLm::new(model.clone());
        let mut dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        let plain =
            generate(&mut lm, &mut dec, &vocab, &prompt, &cfg, &mut Rng::new(seed)).unwrap();

        // Warm a prior with a learning run of the same seed, then freeze
        // it so the measured runs share one deterministic proposer.
        let mut spec = SpeculativeModel::new(0.5);
        {
            let mut lm = MockLm::new(model.clone());
            let mut dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
            let mut r = Rng::new(seed);
            generate_drafted(
                &mut lm, &mut dec, &mut spec, &vocab, &prompt, k_max, prune, &cfg, &mut r,
            )
            .unwrap();
        }
        spec.frozen = true;

        let mut lm = MockLm::new(model.clone());
        let mut dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        let mut r = Rng::new(seed);
        let drafted = generate_drafted(
            &mut lm, &mut dec, &mut spec, &vocab, &prompt, k_max, prune, &cfg, &mut r,
        )
        .unwrap();
        assert_eq!(plain.tokens, drafted.tokens, "drafted != plain ({ctx})");
        assert_eq!(plain.text_bytes, drafted.text_bytes, "{ctx}");

        let mut lm = MockLm::new(model.clone());
        let mut dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        let mut r = Rng::new(seed);
        let specd = generate_speculative(
            &mut lm, &mut dec, &mut spec, &vocab, &prompt, 8, &cfg, &mut r,
        )
        .unwrap();
        assert_eq!(drafted.tokens, specd.tokens, "drafted != speculative ({ctx})");
        assert_eq!(drafted.text_bytes, specd.text_bytes, "{ctx}");
    });
}

#[test]
fn prop_bpe_roundtrip() {
    let vocab = test_vocab();
    check("bpe-roundtrip", 50, |rng| {
        let len = rng.below(200);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let ids = vocab.encode(&bytes);
        assert_eq!(vocab.decode(&ids), bytes);
    });
}

#[test]
fn prop_mask_union_over_lookahead_is_monotone() {
    // Increasing k only ever ADDS tokens (the tree is traversed deeper).
    let vocab = test_vocab();
    let engine = Engine::compile(builtin::json(), vocab.clone()).unwrap();
    check("lookahead-monotone", 10, |rng| {
        let mut walk_dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        let steps = rng.below(20);
        let walked = random_walk(&mut walk_dec, rng, steps);
        let mut masks = Vec::new();
        for k in [Lookahead::K(0), Lookahead::K(1), Lookahead::K(3), Lookahead::Infinite] {
            let mut dec = DominoDecoder::new(engine.clone(), k);
            for &t in &walked {
                dec.advance(t).unwrap();
            }
            masks.push(dec.compute_mask());
        }
        for w in masks.windows(2) {
            for id in 0..engine.vocab.len() as domino::TokenId {
                assert!(
                    !w[0].allowed(id) || w[1].allowed(id),
                    "monotonicity violated for {:?} after {:?}",
                    engine.vocab.token_str(id),
                    engine.vocab.decode_str(&walked),
                );
            }
        }
    });
}

/// A random schema inside the compilable subset (`depth` bounds nesting).
fn random_schema(rng: &mut Rng, depth: usize) -> Json {
    let choice = rng.below(if depth == 0 { 5 } else { 8 });
    match choice {
        0 => Json::obj(vec![("type", Json::str("null"))]),
        1 => Json::obj(vec![("type", Json::str("boolean"))]),
        2 => Json::obj(vec![
            ("type", Json::str("integer")),
            ("minimum", Json::Num(rng.below(5) as f64)),
            ("maximum", Json::Num((10 + rng.below(90)) as f64)),
        ]),
        3 => Json::obj(vec![("type", Json::str("string"))]),
        4 => {
            let vals = ["a", "b", "c", "d"];
            let n = 1 + rng.below(3);
            Json::obj(vec![(
                "enum",
                Json::Arr(vals.iter().take(n).map(|v| Json::str(*v)).collect()),
            )])
        }
        5 => {
            let names = ["alpha", "beta", "gamma"];
            let n = 1 + rng.below(3);
            let mut props = std::collections::BTreeMap::new();
            let mut required = Vec::new();
            for name in names.iter().take(n) {
                props.insert(name.to_string(), random_schema(rng, depth - 1));
                if rng.chance(0.5) {
                    required.push(Json::str(*name));
                }
            }
            let mut fields = vec![
                ("type", Json::str("object")),
                ("properties", Json::Obj(props)),
                ("additionalProperties", Json::Bool(false)),
            ];
            if !required.is_empty() {
                fields.push(("required", Json::Arr(required)));
            }
            Json::obj(fields)
        }
        6 => Json::obj(vec![
            ("type", Json::str("array")),
            ("items", random_schema(rng, depth - 1)),
            ("minItems", Json::Num(rng.below(2) as f64)),
            ("maxItems", Json::Num((2 + rng.below(4)) as f64)),
        ]),
        _ => Json::obj(vec![(
            "anyOf",
            Json::Arr(vec![random_schema(rng, depth - 1), random_schema(rng, depth - 1)]),
        )]),
    }
}

/// Serialize with shuffled object key order and random whitespace — a
/// semantically identical spelling of the same schema.
fn messy_serialize(v: &Json, rng: &mut Rng, out: &mut String) {
    fn pad(rng: &mut Rng, out: &mut String) {
        for _ in 0..rng.below(3) {
            out.push([' ', '\n', '\t'][rng.below(3)]);
        }
    }
    match v {
        Json::Obj(m) => {
            out.push('{');
            let mut keys: Vec<&String> = m.keys().collect();
            rng.shuffle(&mut keys);
            for (i, k) in keys.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(rng, out);
                out.push_str(&Json::str((*k).clone()).to_string());
                pad(rng, out);
                out.push(':');
                pad(rng, out);
                messy_serialize(&m[*k], rng, out);
            }
            pad(rng, out);
            out.push('}');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                    pad(rng, out);
                }
                messy_serialize(x, rng, out);
            }
            out.push(']');
        }
        other => out.push_str(&other.to_string()),
    }
}

#[test]
fn prop_jsonschema_fingerprints_stable_under_normalization() {
    check("jsonschema-fingerprint-normalization", 40, |rng| {
        let schema = random_schema(rng, 2);
        let canonical = schema.to_string();
        let mut scrambled = String::new();
        messy_serialize(&schema, rng, &mut scrambled);
        let a = ConstraintSpec::json_schema(canonical.clone());
        let b = ConstraintSpec::json_schema(scrambled.clone());
        assert_eq!(a.normalized(), b.normalized(), "{canonical} vs {scrambled}");
        assert_eq!(a.fingerprint(), b.fingerprint(), "{canonical} vs {scrambled}");
        // The registry/artifact key folds build parameters in; it must
        // stay spelling-insensitive at every (vocab, k) combination.
        assert_eq!(a.build_fingerprint(7, Some(2)), b.build_fingerprint(7, Some(2)));
        assert_eq!(a.build_fingerprint(9, None), b.build_fingerprint(9, None));
        // Distinct schemas keep distinct keys (semantic, not textual).
        let other = ConstraintSpec::json_schema(r#"{"type": "integer", "minimum": 777}"#);
        assert_ne!(a.fingerprint(), other.fingerprint());
        // Every generated spelling stays inside the compilable subset.
        domino::grammar::jsonschema::compile(&scrambled)
            .unwrap_or_else(|e| panic!("{e:#}: {scrambled}"));
    });
}

#[test]
fn prop_wordwise_mask_kernels_match_scalar_reference() {
    // The word-parallel TokenMask kernels must be bit-identical to the
    // obvious one-token-at-a-time implementation, exactly at the
    // word-boundary sizes where chunked loops and the ghost-bit tail
    // handling can go wrong.
    use domino::domino::TokenMask;
    check("wordwise-vs-scalar", 40, |rng| {
        for &size in &[63usize, 64, 65, 127, 128] {
            let mut a = TokenMask::none(size);
            let mut b = TokenMask::none(size);
            for t in 0..size as domino::TokenId {
                if rng.chance(0.5) {
                    a.allow(t);
                }
                if rng.chance(0.5) {
                    b.allow(t);
                }
            }

            let mut got = a.clone();
            got.intersect(&b);
            for t in 0..size as domino::TokenId {
                assert_eq!(
                    got.allowed(t),
                    a.allowed(t) && b.allowed(t),
                    "intersect at size {size}, token {t}"
                );
            }

            let mut got = a.clone();
            got.and_not(&b);
            for t in 0..size as domino::TokenId {
                assert_eq!(
                    got.allowed(t),
                    a.allowed(t) && !b.allowed(t),
                    "and_not at size {size}, token {t}"
                );
            }

            let scalar_count =
                (0..size as domino::TokenId).filter(|&t| a.allowed(t) && b.allowed(t)).count();
            assert_eq!(a.count_intersect(&b), scalar_count, "count_intersect at size {size}");

            let mut logits: Vec<f32> = (0..size).map(|i| i as f32 * 0.5 - 3.0).collect();
            let mut reference = logits.clone();
            a.apply(&mut logits);
            for t in 0..size {
                if !a.allowed(t as domino::TokenId) {
                    reference[t] = f32::NEG_INFINITY;
                }
            }
            assert_eq!(logits, reference, "apply at size {size}");

            let via_iter: Vec<domino::TokenId> = a.iter().collect();
            let scalar: Vec<domino::TokenId> =
                (0..size as domino::TokenId).filter(|&t| a.allowed(t)).collect();
            assert_eq!(via_iter, scalar, "iter at size {size}");
        }
    });
}

#[test]
fn sharded_mask_cache_survives_concurrent_mixed_load() {
    // 8 threads hammer one sharded cache with a deterministic
    // (variant, state) → mask mapping: no update may be lost or
    // corrupted, the hit/miss counters must account for every `get`,
    // and the size bound must hold.
    use domino::constraint::MaskCache;
    use domino::domino::TokenMask;
    use std::sync::atomic::{AtomicU64, Ordering};

    const CAPACITY: usize = 512;
    const KEYS: u64 = 128; // < capacity: steady state has no evictions
    fn mask_for(state: u64) -> TokenMask {
        let mut m = TokenMask::none(256);
        let mut x = state.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        for _ in 0..10 {
            x ^= x >> 13;
            x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
            m.allow((x % 256) as domino::TokenId);
        }
        m
    }

    let cache = MaskCache::with_shards(CAPACITY, 8);
    let gets = AtomicU64::new(0);
    std::thread::scope(|s| {
        for th in 0..8u64 {
            let cache = &cache;
            let gets = &gets;
            s.spawn(move || {
                let mut rng = Rng::new(th + 1);
                for _ in 0..5_000 {
                    let key = rng.below(KEYS as usize) as u64;
                    match cache.get(0, key) {
                        Some(m) => assert_eq!(*m, mask_for(key), "corrupted entry for key {key}"),
                        None => cache.put(0, key, Arc::new(mask_for(key))),
                    }
                    gets.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    let s = cache.stats();
    assert_eq!(s.hits + s.misses, gets.load(Ordering::Relaxed), "every get is a hit or a miss");
    assert!(s.hits > 0, "steady state must hit");
    assert!(cache.len() as u64 <= KEYS, "no phantom entries");
    assert!(cache.len() <= CAPACITY, "capacity bound");
    // Post-stress, every surviving entry still maps to its mask.
    for key in 0..KEYS {
        if let Some(m) = cache.peek(0, key) {
            assert_eq!(*m, mask_for(key), "post-stress entry for key {key}");
        }
    }
}

#[test]
fn prop_scanner_segmentations_accepted_by_dfas() {
    let g = builtin::json();
    let scanner = domino::scanner::Scanner::new(&g).unwrap();
    let dfas = g.terminal_dfas().unwrap();
    check("scanner-vs-dfas", 30, |rng| {
        // Random JSON-ish byte strings.
        let choices: [&[u8]; 10] =
            [b"{", b"}", b"\"a\"", b"1", b",", b":", b" ", b"[", b"]", b"tr"];
        let mut bytes = Vec::new();
        for _ in 0..rng.below(6) + 1 {
            let i = rng.below(choices.len());
            bytes.extend_from_slice(choices[i]);
        }
        for (seq, posset) in scanner.traverse(&[domino::scanner::Pos::Boundary], &bytes) {
            // Every completed terminal must be an actual DFA-accepted
            // split of a prefix of `bytes` — verify by replaying greedily:
            // reconstructing exact split positions would duplicate the
            // scanner, so check the weaker sound property that each
            // emitted terminal id is valid and the pending positions are
            // live states of their DFAs.
            for t in &seq {
                assert!((*t as usize) < dfas.len());
            }
            for p in posset {
                if let domino::scanner::Pos::In(t, s) = p {
                    assert!((s as usize) < dfas[t as usize].num_states());
                }
            }
        }
    });
}
