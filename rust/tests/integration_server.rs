//! Server integration over the mock LM: admission, constrained
//! generation, continuous batching fairness, metrics, TCP protocol.

use domino::runtime::mock::{json_mock, MockFactory};
use domino::server::engine::{Constraint, ConstraintSpec, EngineCtx, GenRequest, Server};
use domino::server::tcp::{format_response, parse_request};
use domino::util::Json;

fn mock_server(slots: usize) -> Server {
    Server::start(
        move || {
            let (vocab, model) = json_mock(512);
            Ok(EngineCtx::new(Box::new(MockFactory { model }), vocab))
        },
        slots,
    )
}

#[test]
fn serves_unconstrained_and_constrained() {
    let server = mock_server(2);
    let r = server
        .generate(GenRequest {
            prompt: "{\"name\": ".into(),
            constraint: Constraint::none(),
            max_tokens: 32,
            ..Default::default()
        })
        .unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);

    let r = server
        .generate(GenRequest {
            prompt: String::new(),
            constraint: Constraint::domino(ConstraintSpec::builtin("json")),
            max_tokens: 64,
            ..Default::default()
        })
        .unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    if r.stats.stopped {
        Json::parse(&r.text).unwrap_or_else(|e| panic!("{e:#}: {}", r.text));
    }
    server.shutdown();
}

#[test]
fn speculative_requests_share_priors() {
    let server = mock_server(1);
    let req = GenRequest {
        prompt: String::new(),
        constraint: Constraint::domino(ConstraintSpec::builtin("gsm8k")).with_speculation(8),
        max_tokens: 48,
        ..Default::default()
    };
    // First request warms the shared prior; later ones speculate.
    let _ = server.generate(req.clone()).unwrap();
    let _ = server.generate(req.clone()).unwrap();
    let r3 = server.generate(req).unwrap();
    assert!(r3.error.is_none());
    assert!(r3.stats.spec_accepted > 0, "{:?}", r3.stats);
    let m = server.metrics().unwrap();
    assert!(m.spec_accepted > 0);
    assert_eq!(m.requests_completed, 3);
    server.shutdown();
}

#[test]
fn concurrent_requests_complete() {
    let server = std::sync::Arc::new(mock_server(4));
    let mut receivers = Vec::new();
    for i in 0..6 {
        receivers.push(server.submit(GenRequest {
            prompt: String::new(),
            constraint: Constraint::domino(ConstraintSpec::builtin("json")),
            max_tokens: 24,
            seed: i,
            temperature: Some(1.0),
            ..Default::default()
        }));
    }
    for rx in receivers {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    let m = server.metrics().unwrap();
    assert_eq!(m.requests_completed, 6);
    assert!(m.tokens_generated > 0);
}

#[test]
fn bad_grammar_reports_error() {
    let server = mock_server(1);
    let r = server
        .generate(GenRequest {
            constraint: Constraint::domino(ConstraintSpec::builtin("no-such-grammar")),
            ..Default::default()
        })
        .unwrap();
    assert!(r.error.is_some());
    server.shutdown();
}

#[test]
fn tcp_protocol_roundtrip() {
    let req =
        parse_request(r#"{"prompt": "p", "grammar": "json", "method": "domino", "max_tokens": 8}"#)
            .unwrap();
    assert_eq!(req.max_tokens, 8);
    let server = mock_server(1);
    let resp = server.generate(req).unwrap();
    let line = format_response(&resp);
    let v = Json::parse(&line).unwrap();
    assert!(v.get("tokens").is_some());
    server.shutdown();
}
