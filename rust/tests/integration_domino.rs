//! End-to-end DOMINO integration over the mock LM: every builtin grammar,
//! minimal invasiveness, lookahead ablation shape, speculation.

use domino::domino::decoder::{Engine, Lookahead};
use domino::domino::{
    generate, generate_speculative, Checker, DominoDecoder, GenConfig, MaskMode,
    SpeculativeModel, Unconstrained,
};
use domino::grammar::builtin;
use domino::runtime::mock::{json_mock, MockLm, MockModel};
use domino::runtime::sampler::Sampling;
use domino::tokenizer::Vocab;
use domino::util::{Json, Rng};
use std::sync::Arc;

fn setup() -> (Arc<Vocab>, Arc<MockModel>) {
    json_mock(512)
}

#[test]
fn every_builtin_grammar_compiles_into_an_engine() {
    let (vocab, _) = setup();
    for name in builtin::GRAMMAR_NAMES {
        let cfg = builtin::by_name(name).unwrap();
        let engine = Engine::compile(cfg, vocab.clone())
            .unwrap_or_else(|e| panic!("engine for {name}: {e:#}"));
        assert_eq!(engine.trees.num_trees(), engine.scanner.num_pos(), "{name}");
    }
}

#[test]
fn constrained_output_is_always_grammatical() {
    // Whatever the model does (even temperature sampling), DOMINO output
    // must parse under the JSON oracle.
    let (vocab, model) = setup();
    let engine = Engine::compile(builtin::json(), vocab.clone()).unwrap();
    for seed in 0..5 {
        let mut lm = MockLm::new(model.clone());
        let mut dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        let cfg = GenConfig {
            max_tokens: 96,
            sampling: Sampling::Temperature(1.0),
            mode: MaskMode::FullMask,
        };
        let r = generate(&mut lm, &mut dec, &vocab, &domino::domino::generate::Prompt::default(), &cfg, &mut Rng::new(seed)).unwrap();
        let text = r.text();
        if r.stopped {
            // Complete generation must be valid JSON.
            Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e:#}\n{text}"));
        } else {
            // Truncated generation must still be a viable prefix: the
            // decoder must still be alive.
            assert!(dec.alive(), "seed {seed}");
        }
    }
}

#[test]
fn minimally_invasive_matches_unconstrained_when_output_valid() {
    let (vocab, model) = setup();
    let engine = Engine::compile(builtin::json(), vocab.clone()).unwrap();
    let cfg =
        GenConfig { max_tokens: 64, sampling: Sampling::Greedy, mode: MaskMode::Opportunistic };

    let mut lm = MockLm::new(model.clone());
    let mut unc = Unconstrained::new(vocab.len());
    let base = generate(&mut lm, &mut unc, &vocab, &domino::domino::generate::Prompt::default(), &cfg, &mut Rng::new(9)).unwrap();
    let base_text = base.text();
    assert!(Json::parse_prefix(&base_text).is_ok(), "mock emits JSON: {base_text}");

    let mut lm = MockLm::new(model);
    let mut dec = DominoDecoder::new(engine, Lookahead::Infinite);
    let cons = generate(&mut lm, &mut dec, &vocab, &domino::domino::generate::Prompt::default(), &cfg, &mut Rng::new(9)).unwrap();
    assert_eq!(base_text, cons.text());
    assert_eq!(cons.interventions, 0, "Def. 2.1: no interventions on valid output");
}

#[test]
fn lookahead_ablation_shape_table4() {
    // Table 4's qualitative shape on the mock: k=0 intervenes (much) more
    // than k=∞; k=∞ does not intervene at all.
    let (vocab, model) = setup();
    let engine = Engine::compile(builtin::json(), vocab.clone()).unwrap();
    let cfg = GenConfig { max_tokens: 64, sampling: Sampling::Greedy, mode: MaskMode::FullMask };
    let mut interventions = Vec::new();
    for k in [Lookahead::K(0), Lookahead::K(1), Lookahead::Infinite] {
        let mut lm = MockLm::new(model.clone());
        let mut dec = DominoDecoder::new(engine.clone(), k);
        let r = generate(&mut lm, &mut dec, &vocab, &domino::domino::generate::Prompt::default(), &cfg, &mut Rng::new(4)).unwrap();
        interventions.push(r.interventions);
    }
    assert!(
        interventions[0] > interventions[2],
        "k=0 must intervene more than k=inf: {interventions:?}"
    );
    assert_eq!(interventions[2], 0);
}

#[test]
fn speculation_reduces_model_calls_on_schema() {
    // Fig. 5's mechanism: on a schema-driven grammar, the count model
    // predicts the fixed skeleton and chunked verification saves calls.
    let (vocab, model) = setup();
    let engine = Engine::compile(builtin::gsm8k_schema(), vocab.clone()).unwrap();
    let cfg =
        GenConfig { max_tokens: 72, sampling: Sampling::Greedy, mode: MaskMode::Opportunistic };

    // Plain run.
    let mut lm = MockLm::new(model.clone());
    let mut dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
    let plain = generate(&mut lm, &mut dec, &vocab, &domino::domino::generate::Prompt::default(), &cfg, &mut Rng::new(2)).unwrap();

    // Warmup + frozen speculative run.
    let mut spec = SpeculativeModel::new(0.5);
    for seed in [2, 3] {
        let mut lm = MockLm::new(model.clone());
        let mut dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        generate_speculative(&mut lm, &mut dec, &mut spec, &vocab, &domino::domino::generate::Prompt::default(), 10, &cfg, &mut Rng::new(seed))
            .unwrap();
    }
    spec.frozen = true;
    let mut lm = MockLm::new(model);
    let mut dec = DominoDecoder::new(engine, Lookahead::Infinite);
    let specd =
        generate_speculative(&mut lm, &mut dec, &mut spec, &vocab, &domino::domino::generate::Prompt::default(), 10, &cfg, &mut Rng::new(2))
            .unwrap();

    assert_eq!(plain.tokens, specd.tokens, "speculation must not change output");
    assert!(specd.spec_accepted > 0);
    assert!(specd.model_calls < plain.model_calls, "{} vs {}", specd.model_calls, plain.model_calls);
}

#[test]
fn xml_and_template_grammars_generate() {
    // Grammar-only smoke for the recursive XML grammar and the fixed
    // template: drive the decoder with the first allowed token and check
    // it never deadlocks.
    let vocab = Arc::new(Vocab::byte_level());
    for name in ["xml", "template"] {
        let engine = Engine::compile(builtin::by_name(name).unwrap(), vocab.clone()).unwrap();
        let mut dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
        let mut out = Vec::new();
        for _ in 0..120 {
            let mask = dec.compute_mask();
            assert!(!mask.is_empty(), "{name}: deadlock after {:?}", vocab.decode_str(&out));
            let tok = mask.iter().find(|&t| t != domino::tokenizer::EOS_ID);
            match tok {
                Some(t) => {
                    dec.advance(t).unwrap();
                    out.push(t);
                }
                None => break,
            }
        }
        assert!(!out.is_empty(), "{name}");
    }
}

#[test]
fn c_grammar_accepts_real_programs() {
    let (vocab, _) = setup();
    let engine = Engine::compile(builtin::c_lang(), vocab.clone()).unwrap();
    let program = "int main() {\n  int a = 3;\n  int b = 4;\n  return a + b;\n}";
    let mut dec = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
    dec.advance_bytes(program.as_bytes()).unwrap();
    assert!(dec.check_token(domino::tokenizer::EOS_ID), "complete program accepts EOS");
    // Rejects garbage.
    let mut dec2 = DominoDecoder::new(engine, Lookahead::Infinite);
    assert!(dec2.advance_bytes(b"int x = 1;;;; }{").is_err());
}
