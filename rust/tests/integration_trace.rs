//! Tracing integration over the mock LM: span-tree well-formedness on a
//! real decode, one decision record per emitted token, token parity with
//! tracing on vs off, the `{"op":"trace"}` ring dump (eviction order,
//! inline `"trace": true` summaries) over TCP, and Perfetto trace-event
//! JSON written via `trace_dir` — the same file `e2e_serving` emits.

use domino::constraint::{Constraint, ConstraintSpec};
use domino::runtime::mock::{json_mock, MockFactory};
use domino::server::engine::{EngineCtx, GenRequest};
use domino::server::scheduler::{Scheduler, SchedulerConfig};
use domino::server::tcp;
use domino::server::trace::{render_timeline, CaptureCause, TraceConfig};
use domino::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

/// Mock-LM scheduler with an explicit tracing policy.
fn traced_sched(engines: usize, slots: usize, trace: TraceConfig) -> Scheduler {
    let (vocab, model) = json_mock(512);
    Scheduler::start(
        move |_shard, registry| {
            Ok(EngineCtx::with_registry(
                Box::new(MockFactory { model: model.clone() }),
                vocab.clone(),
                registry,
            ))
        },
        SchedulerConfig {
            engines,
            slots_per_engine: slots,
            queue_depth: 64,
            trace,
            ..SchedulerConfig::default()
        },
    )
}

fn req(grammar: &str, max_tokens: usize, seed: u64) -> GenRequest {
    GenRequest {
        constraint: Constraint::domino(ConstraintSpec::builtin(grammar)),
        max_tokens,
        temperature: Some(1.0),
        seed,
        ..Default::default()
    }
}

/// A throwaway per-test trace directory (unique per process + label).
fn temp_trace_dir(label: &str) -> PathBuf {
    std::env::temp_dir().join(format!("domino-trace-test-{}-{label}", std::process::id()))
}

#[test]
fn span_tree_is_well_formed_on_a_real_decode() {
    let sched = traced_sched(1, 2, TraceConfig { sample_rate: 1.0, ..TraceConfig::default() });
    let r = sched.generate(req("json", 24, 1)).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    let recent = sched.tracer().recent();
    assert_eq!(recent.len(), 1, "sample_rate 1.0 captures the request");
    let t = &recent[0];
    assert_eq!(t.cause, CaptureCause::Sampled);
    assert!(t.ticks >= 1, "a decode must record ticks");

    // Every span closes (end >= start) and sits inside the request span.
    let span = |name: &str| t.spans.iter().find(|s| s.name == name).unwrap();
    let request = span("request");
    for s in &t.spans {
        assert!(s.end_us >= s.start_us, "span {} must close", s.name);
        assert!(
            s.start_us >= request.start_us && s.end_us <= request.end_us,
            "span {} must nest inside request",
            s.name
        );
    }
    // queue and decode partition the request's life; ticks nest under
    // decode; the four phases tile each tick exactly.
    let decode = span("decode");
    assert!(span("queue").end_us <= decode.start_us + 1, "queue ends where decode starts");
    let ticks: Vec<_> = t.spans.iter().filter(|s| s.name == "tick").collect();
    assert_eq!(ticks.len() as u64, t.ticks);
    for tick in &ticks {
        assert!(
            tick.start_us >= decode.start_us && tick.end_us <= decode.end_us,
            "ticks nest under decode"
        );
        let mut cursor = tick.start_us;
        for phase in ["decide", "gather", "forward", "finish"] {
            let s = t
                .spans
                .iter()
                .find(|s| s.name == phase && s.start_us == cursor && s.end_us <= tick.end_us)
                .unwrap_or_else(|| panic!("{phase} span tiling tick at {cursor}us"));
            cursor = s.end_us;
        }
        assert_eq!(cursor, tick.end_us, "phases tile the tick exactly");
    }

    // One decision record per emitted token, indices dense from 0.
    assert_eq!(t.decisions.len(), r.stats.tokens_out, "one decision per emitted token");
    for (i, d) in t.decisions.iter().enumerate() {
        assert_eq!(d.index, i, "decision indices must be dense and ordered");
        assert_eq!(d.origin, "sampled", "plain decode commits sampled tokens");
    }
    sched.shutdown();
}

#[test]
fn token_stream_is_identical_with_tracing_on_and_off() {
    let off = traced_sched(1, 2, TraceConfig::default());
    let on = traced_sched(1, 2, TraceConfig { sample_rate: 1.0, ..TraceConfig::default() });
    for seed in [3, 17, 99] {
        let a = off.generate(req("json", 32, seed)).unwrap();
        let b = on.generate(req("json", 32, seed)).unwrap();
        assert!(a.error.is_none() && b.error.is_none());
        assert_eq!(a.text, b.text, "tracing must never change tokens (seed {seed})");
        assert_eq!(a.stats.tokens_out, b.stats.tokens_out);
    }
    assert_eq!(off.tracer().recent().len(), 0, "disabled tracer captures nothing");
    assert_eq!(on.tracer().recent().len(), 3);
    off.shutdown();
    on.shutdown();
}

#[test]
fn trace_op_dumps_ring_in_eviction_order() {
    // Ring capacity 3, five sequential requests on one single-slot shard:
    // the dump must hold the newest three, oldest first.
    let trace = TraceConfig { sample_rate: 1.0, ring_capacity: 3, ..TraceConfig::default() };
    let sched = Arc::new(traced_sched(1, 1, trace));
    for seed in 0..5 {
        let r = sched.generate(req("json", 8, seed)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    let addr = tcp::spawn_serve(sched.clone(), "127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(addr).unwrap();
    writeln!(conn, r#"{{"op": "trace"}}"#).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    let traces = v.get("traces").and_then(|t| t.as_arr()).unwrap();
    assert_eq!(traces.len(), 3, "ring capacity bounds the dump: {line}");
    let ids: Vec<f64> =
        traces.iter().map(|t| t.get("id").and_then(|i| i.as_f64()).unwrap()).collect();
    assert_eq!(ids, [3.0, 4.0, 5.0], "oldest evicted first, dump oldest-first");
    for t in traces {
        assert!(t.get("spans").and_then(|s| s.as_arr()).is_some_and(|s| !s.is_empty()));
        assert!(t.get("decisions").and_then(|d| d.as_arr()).is_some_and(|d| !d.is_empty()));
        assert_eq!(t.get("cause").and_then(|c| c.as_str()), Some("sampled"));
    }
}

#[test]
fn wire_trace_flag_returns_inline_summary() {
    // Tracing otherwise fully off: a `"trace": true` request is still
    // captured and answered with an inline summary.
    let sched = Arc::new(traced_sched(1, 1, TraceConfig::default()));
    let addr = tcp::spawn_serve(sched.clone(), "127.0.0.1:0").unwrap();
    let mut conn = TcpStream::connect(addr).unwrap();
    writeln!(conn, r#"{{"prompt": "", "grammar": "json", "max_tokens": 8, "trace": true}}"#)
        .unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert_eq!(v.get("error"), Some(&Json::Null), "{line}");
    let summary = v.get("trace").expect("inline trace summary");
    assert_eq!(summary.get("cause").and_then(|c| c.as_str()), Some("requested"));
    assert!(summary.get("ticks").and_then(|t| t.as_f64()).is_some_and(|t| t >= 1.0));
    assert!(summary.get("decisions").and_then(|d| d.as_f64()).is_some_and(|d| d >= 1.0));

    // An untraced request on the same connection carries no trace key.
    writeln!(conn, r#"{{"prompt": "", "grammar": "json", "max_tokens": 8}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert_eq!(v.get("trace"), None, "{line}");
    assert_eq!(sched.tracer().recent().len(), 1, "only the requested trace was captured");
}

#[test]
fn trace_dir_writes_loadable_perfetto_json() {
    let dir = temp_trace_dir("perfetto");
    let _ = std::fs::remove_dir_all(&dir);
    let trace =
        TraceConfig { sample_rate: 1.0, trace_dir: Some(dir.clone()), ..TraceConfig::default() };
    let sched = traced_sched(1, 1, trace);
    let r = sched.generate(req("json", 16, 5)).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    sched.shutdown();

    let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .expect("trace dir created")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .collect();
    files.sort();
    assert_eq!(files.len(), 1, "one captured request, one trace file");
    let name = files[0].file_name().unwrap().to_str().unwrap();
    assert!(name.starts_with("trace-") && name.ends_with(".json"), "perfetto naming: {name}");

    // The file is valid Chrome trace-event JSON with complete-event
    // spans for every tick phase — Perfetto's loadable format.
    let raw = std::fs::read_to_string(&files[0]).unwrap();
    let parsed = Json::parse(&raw).expect("trace file parses as JSON");
    let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
    let complete = |name: &str| {
        events
            .iter()
            .filter(|e| {
                e.get("ph").and_then(|p| p.as_str()) == Some("X")
                    && e.get("name").and_then(|n| n.as_str()) == Some(name)
            })
            .count()
    };
    let ticks = complete("tick");
    assert!(ticks >= 1, "decode must record ticks");
    for phase in ["decide", "gather", "forward", "finish"] {
        assert_eq!(complete(phase), ticks, "{phase} span present for every tick");
    }
    assert_eq!(complete("request"), 1);
    assert_eq!(complete("decode"), 1);
    let instants = events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("i"))
        .count();
    assert!(instants >= r.stats.tokens_out, "one instant per decision at minimum");

    // The CLI renderer consumes the same file.
    let timeline = render_timeline(&parsed).expect("domino trace renders the file");
    assert!(timeline.contains("tick #0"));
    assert!(timeline.contains("forward"));
    let _ = std::fs::remove_dir_all(&dir);
}
