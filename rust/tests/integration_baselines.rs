//! Baseline integration: online checker equivalence during generation,
//! template programs end-to-end (± healing, ± WS), Fig. 1/2 phenomena.

use domino::baselines::template::{
    conll_program, gsm8k_program, person_program, rpg_program, TemplateRuntime,
};
use domino::baselines::OnlineChecker;
use domino::domino::decoder::{Engine, Lookahead};
use domino::domino::{generate, Checker, DominoDecoder, GenConfig, MaskMode};
use domino::grammar::builtin;
use domino::runtime::mock::{json_mock, MockLm};
use domino::runtime::sampler::Sampling;
use domino::util::{Json, Rng};

#[test]
fn online_and_domino_generate_identically() {
    // Same grammar, same model, same seed → identical outputs (both are
    // minimally invasive); they differ only in cost.
    let (vocab, model) = json_mock(512);
    let engine = Engine::compile(builtin::json(), vocab.clone()).unwrap();
    let cfg = GenConfig { max_tokens: 48, sampling: Sampling::Temperature(0.8), mode: MaskMode::FullMask };

    let mut lm = MockLm::new(model.clone());
    let mut dom = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
    let a = generate(&mut lm, &mut dom, &vocab, &domino::domino::generate::Prompt::default(), &cfg, &mut Rng::new(11)).unwrap();

    let mut lm = MockLm::new(model);
    let mut online = OnlineChecker::new(engine);
    let b = generate(&mut lm, &mut online, &vocab, &domino::domino::generate::Prompt::default(), &cfg, &mut Rng::new(11)).unwrap();

    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.interventions, b.interventions);
}

#[test]
fn template_programs_produce_parseable_output() {
    let (vocab, model) = json_mock(512);
    for (name, program) in [
        ("person", person_program()),
        ("rpg", rpg_program()),
        ("gsm8k", gsm8k_program(1)),
        ("conll", conll_program(2)),
    ] {
        for healing in [false, true] {
            let rt = TemplateRuntime::compile(program.clone(), vocab.clone(), healing).unwrap();
            let mut lm = MockLm::new(model.clone());
            let r = rt
                .run(&mut lm, &[], Sampling::Greedy, &mut Rng::new(5))
                .unwrap_or_else(|e| panic!("{name} healing={healing}: {e:#}"));
            Json::parse(&r.text)
                .unwrap_or_else(|e| panic!("{name} healing={healing}: {e:#}\n{}", r.text));
            assert!(r.model_calls < r.tokens.len() + 2, "{name}: template must save calls");
        }
    }
}

#[test]
fn ws_flexible_uses_more_model_calls() {
    // App. A: the WS variant generates whitespace with the model → more
    // calls, fewer forced tokens (that is why Table 2 shows ~0.5-0.8×
    // throughput for GUIDANCE WS).
    let (vocab, model) = json_mock(512);
    let fixed = TemplateRuntime::compile(rpg_program(), vocab.clone(), true).unwrap();
    let ws = TemplateRuntime::compile(rpg_program().ws_flexible(), vocab.clone(), true).unwrap();

    let mut lm = MockLm::new(model.clone());
    let a = fixed.run(&mut lm, &[], Sampling::Greedy, &mut Rng::new(1)).unwrap();
    let mut lm = MockLm::new(model);
    let b = ws.run(&mut lm, &[], Sampling::Greedy, &mut Rng::new(1)).unwrap();

    assert!(b.model_calls > a.model_calls, "{} vs {}", b.model_calls, a.model_calls);
    // The WS holes are generated, not forced.
    assert!(b.gen_tokens > a.gen_tokens, "{} vs {}", b.gen_tokens, a.gen_tokens);
}

#[test]
fn fig1_greedy_constraining_distorts() {
    // The Fig. 1 phenomenon end-to-end: k=0 output differs from
    // unconstrained/k=∞ output and the model likes it less (perplexity).
    let (vocab, model) = json_mock(512);
    let engine = Engine::compile(builtin::json(), vocab.clone()).unwrap();
    let cfg = GenConfig { max_tokens: 48, sampling: Sampling::Greedy, mode: MaskMode::FullMask };

    let mut lm = MockLm::new(model.clone());
    let mut d_inf = DominoDecoder::new(engine.clone(), Lookahead::Infinite);
    let r_inf = generate(&mut lm, &mut d_inf, &vocab, &domino::domino::generate::Prompt::default(), &cfg, &mut Rng::new(7)).unwrap();

    let mut lm = MockLm::new(model);
    let mut d0 = DominoDecoder::new(engine, Lookahead::K(0));
    let r0 = generate(&mut lm, &mut d0, &vocab, &domino::domino::generate::Prompt::default(), &cfg, &mut Rng::new(7)).unwrap();

    assert!(r0.interventions > 0);
    assert!(r0.perplexity() > r_inf.perplexity(), "{} vs {}", r0.perplexity(), r_inf.perplexity());
}

#[test]
fn fig2_template_output_has_higher_perplexity_than_natural() {
    // Fig. 2: the template-forced tokenization scores worse under the
    // model than the model-preferred (retokenized) form of the same text.
    let (vocab, model) = json_mock(512);
    let rt = TemplateRuntime::compile(person_program(), vocab.clone(), false).unwrap();
    let mut lm = MockLm::new(model.clone());
    let r = rt.run(&mut lm, &[], Sampling::Greedy, &mut Rng::new(3)).unwrap();

    // Naturalize the same text (Alg. 3): the model-preferred tokenization
    // must DIFFER from the template's externally-forced one — that
    // divergence is precisely template-induced misalignment (the paper
    // does not claim greedy retokenization is globally optimal, only that
    // it reveals the model's preference).
    let mut lm2 = MockLm::new(model);
    let nat = domino::eval::retokenize::retokenize(&mut lm2, &vocab, &[], r.text.as_bytes()).unwrap();
    assert_eq!(vocab.decode(&nat.tokens), r.text.as_bytes(), "same text");
    assert_ne!(nat.tokens, r.tokens, "tokenizations must diverge (misalignment)");
}

#[test]
fn online_checker_agrees_with_domino_across_grammars() {
    let (vocab, model) = json_mock(512);
    for name in ["gsm8k", "xml"] {
        let engine = Engine::compile(builtin::by_name(name).unwrap(), vocab.clone()).unwrap();
        let mut online = OnlineChecker::new(engine.clone());
        let mut dom = DominoDecoder::new(engine, Lookahead::Infinite);
        // Drive both through whatever the model emits under DOMINO.
        let cfg = GenConfig { max_tokens: 24, sampling: Sampling::Greedy, mode: MaskMode::FullMask };
        let mut lm = MockLm::new(model.clone());
        let r = generate(&mut lm, &mut dom, &vocab, &domino::domino::generate::Prompt::default(), &cfg, &mut Rng::new(1)).unwrap();
        let mut dom2 = DominoDecoder::new(
            Engine::compile(builtin::by_name(name).unwrap(), vocab.clone()).unwrap(),
            Lookahead::Infinite,
        );
        for &t in &r.tokens {
            assert_eq!(online.compute_mask(), dom2.compute_mask(), "{name} @ {t}");
            online.advance(t).unwrap();
            dom2.advance(t).unwrap();
        }
    }
}

#[test]
fn template_as_grammar_runs_under_domino() {
    // §3.5: execute a GUIDANCE program via DOMINO — the template compiles
    // to a CFG and the decoder enforces it minimally invasively.
    let (vocab, model) = json_mock(512);
    let grammar = person_program().to_grammar().unwrap();
    let engine = Engine::compile(grammar, vocab.clone()).unwrap();
    let cfg = GenConfig { max_tokens: 64, sampling: Sampling::Greedy, mode: MaskMode::FullMask };
    let mut lm = MockLm::new(model);
    let mut dec = DominoDecoder::new(engine, Lookahead::Infinite);
    let r = generate(
        &mut lm,
        &mut dec,
        &vocab,
        &domino::domino::generate::Prompt::default(),
        &cfg,
        &mut Rng::new(3),
    )
    .unwrap();
    // Output satisfies the template structure AND parses as JSON.
    let v = Json::parse(&r.text()).unwrap_or_else(|e| panic!("{e:#}: {}", r.text()));
    assert!(v.get("name").is_some() && v.get("age").is_some() && v.get("occupation").is_some());
    // Unlike the template executor, every token is model-chosen: the
    // decoder can intervene, but never injects externally-tokenized text.
    assert!(!r.tokens.is_empty());
}

#[test]
fn template_grammar_rejects_wrong_structure() {
    let grammar = person_program().to_grammar().unwrap();
    let (vocab, _) = json_mock(512);
    let engine = Engine::compile(grammar, vocab).unwrap();
    let mut dec = DominoDecoder::new(engine, Lookahead::Infinite);
    // The RPG field order is wrong for the person template.
    assert!(dec.advance_bytes(b"{\"id\": 3").is_err());
    let mut dec2 = DominoDecoder::new(
        Engine::compile(person_program().to_grammar().unwrap(), std::sync::Arc::new(domino::tokenizer::Vocab::byte_level())).unwrap(),
        Lookahead::Infinite,
    );
    dec2.advance_bytes(b"{\"name\": \"Jo").unwrap();
}
