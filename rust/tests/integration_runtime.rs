//! Runtime integration: load the AOT bundle and drive the PJRT session.
//!
//! These tests need `artifacts/` (built by `make artifacts`, or pointed to
//! by `DOMINO_ARTIFACTS`); they are skipped with a notice otherwise so
//! `cargo test` stays green on a fresh checkout.

use domino::runtime::pjrt::{artifacts_dir, load_vocab, PjrtLm, PjrtModel};
use domino::runtime::sampler::argmax;
use domino::runtime::LmSession;
use domino::tokenizer::EOS_ID;

macro_rules! require_artifacts {
    () => {{
        let dir = artifacts_dir();
        if !dir.join("model_config.json").exists() {
            eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
            return;
        }
        dir
    }};
}

#[test]
fn loads_bundle_and_runs_all_variants() {
    let dir = require_artifacts!();
    let model = PjrtModel::load(&dir).expect("load bundle");
    let v = model.config.vocab_size;
    for b in model.batch_widths() {
        for c in model.chunk_sizes(b) {
            let cache = model.new_cache(b).unwrap();
            let kv_len = vec![0i32; b];
            let tokens = vec![5i32; b * c];
            let (lp, _) = model.run(b, c, &cache, &kv_len, &tokens, None).unwrap();
            assert_eq!(lp.len(), b * c * v, "variant b{b} c{c}");
            // log-probs normalize.
            let row = &lp[..v];
            let total: f64 = row.iter().map(|&x| (x as f64).exp()).sum();
            assert!((total - 1.0).abs() < 1e-3, "b{b} c{c}: sum {total}");
        }
    }
}

#[test]
fn session_chunking_is_consistent() {
    // Appending tokens in different chunkings must give the same logits
    // (the KV cache plumbing is exact, not approximate).
    let dir = require_artifacts!();
    let model = PjrtModel::load(&dir).expect("load bundle");
    let vocab = load_vocab(&dir).unwrap();
    let text = b"A person encoded as JSON object:\n{\"name\"";
    let ids = vocab.encode(text);
    assert!(ids.len() >= 4);

    let mut one = PjrtLm::new(model.clone()).unwrap();
    let mut row_one = None;
    for &t in &ids {
        row_one = Some(one.append(&[t]).unwrap());
    }
    let mut bulk = PjrtLm::new(model.clone()).unwrap();
    let row_bulk = bulk.append(&ids).unwrap();

    let a = row_one.unwrap();
    for (i, (x, y)) in a.iter().zip(&row_bulk).enumerate() {
        assert!((x - y).abs() < 1e-3, "logit {i}: {x} vs {y}");
    }
}

#[test]
fn append_scored_matches_append_rows() {
    let dir = require_artifacts!();
    let model = PjrtModel::load(&dir).expect("load bundle");
    let vocab = load_vocab(&dir).unwrap();
    let ids = vocab.encode(b"Q: Tom has 3 apples");
    let mut a = PjrtLm::new(model.clone()).unwrap();
    let rows = a.append_scored(&ids).unwrap();
    assert_eq!(rows.len(), ids.len());
    let mut b = PjrtLm::new(model).unwrap();
    let last = b.append(&ids).unwrap();
    for (x, y) in rows.last().unwrap().iter().zip(&last) {
        assert!((x - y).abs() < 1e-3);
    }
}

#[test]
fn rollback_recovers_state() {
    let dir = require_artifacts!();
    let model = PjrtModel::load(&dir).expect("load bundle");
    let vocab = load_vocab(&dir).unwrap();
    let ids = vocab.encode(b"A person encoded as JSON object:\n");
    let mut lm = PjrtLm::new(model).unwrap();
    let before = lm.append(&ids).unwrap();
    // Append a detour, roll it back, re-append: same logits.
    let detour = vocab.encode(b"xyz");
    lm.append(&detour).unwrap();
    lm.rollback(detour.len()).unwrap();
    assert_eq!(lm.len(), ids.len());
    // Re-deriving the same row requires re-appending the last token.
    lm.rollback(1).unwrap();
    let again = lm.append(&[*ids.last().unwrap()]).unwrap();
    for (x, y) in before.iter().zip(&again) {
        assert!((x - y).abs() < 1e-3);
    }
}

#[test]
fn trained_model_emits_structured_text() {
    // The build-time-trained model, greedily decoded after a corpus-style
    // prompt, should produce JSON-ish bytes and stop via EOS eventually.
    let dir = require_artifacts!();
    let model = PjrtModel::load(&dir).expect("load bundle");
    let vocab = load_vocab(&dir).unwrap();
    let mut lm = PjrtLm::new(model).unwrap();
    let mut logits = lm.append(&vocab.encode(b"A person encoded as JSON object:\n")).unwrap();
    let mut out = Vec::new();
    for _ in 0..60 {
        let t = argmax(&logits);
        if t == EOS_ID {
            break;
        }
        out.push(t);
        logits = lm.append(&[t]).unwrap();
    }
    let text = vocab.decode_str(&out);
    assert!(text.contains('{') || text.contains('"'), "unexpected output: {text:?}");
}
