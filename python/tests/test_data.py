"""Data substrate tests: task generators produce checkable answers in the
paper's schemas; the python BPE round-trips and matches the rust id
layout."""

import json
import random

from compile import data as data_mod


def test_gsm8k_answers_are_correct():
    rng = random.Random(7)
    for _ in range(50):
        q, answer, ans = data_mod.gsm8k_task(rng)
        obj = json.loads(answer)
        assert obj["answer"] == ans
        assert obj["thoughts"], q
        th = obj["thoughts"][0]
        # The calculation evaluates to the result.
        assert eval(th["calculation"]) == th["result"] == ans


def test_conll_entities_in_sentence():
    rng = random.Random(8)
    for _ in range(50):
        sent, answer, ents = data_mod.conll_task(rng)
        obj = json.loads(answer)
        got = [(e["entity"], e["type"]) for e in obj["entities"]]
        assert got == ents
        for name, _ in ents:
            assert name in sent


def test_corpus_docs_parse():
    docs = data_mod.make_corpus(seed=1, docs_per_kind=10)
    assert len(docs) > 30
    json_docs = [d for d in docs if d.startswith(data_mod.PERSON_PROMPT)]
    assert json_docs
    for d in json_docs:
        json.loads(d[len(data_mod.PERSON_PROMPT):])


def test_bpe_roundtrip_and_layout():
    corpus = b'{"name": "John Doe", "age": 35} ' * 50
    tok = data_mod.train_bpe(corpus, 300)
    assert tok.vocab_size > data_mod.NUM_SPECIAL + 256
    ids = tok.encode(corpus)
    assert tok.decode(ids) == corpus
    assert len(ids) < len(corpus)
    # id layout: specials then bytes.
    assert tok.tokens[data_mod.NUM_SPECIAL + ord("a")] == b"a"


def test_bpe_save_load(tmp_path):
    tok = data_mod.train_bpe(b"abab" * 40, 280)
    p = tmp_path / "tok.json"
    tok.save(str(p))
    tok2 = data_mod.Tokenizer.load(str(p))
    assert tok2.encode(b"ababab") == tok.encode(b"ababab")
