"""L2 model tests: shapes, KV-cache semantics, pallas vs ref path, and the
train-path ↔ serve-path agreement that makes build-time training valid for
the Pallas-served model."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as model_mod

CFG = model_mod.Config(vocab_size=128, d_model=32, n_layers=2, n_heads=2, d_ff=64, max_seq=128)


def params():
    return model_mod.init_params(CFG, jax.random.PRNGKey(0))


def test_param_manifest_matches_init():
    p = params()
    man = model_mod.param_manifest(CFG)
    assert set(p.keys()) == {name for name, _ in man}
    for name, shape in man:
        assert p[name].shape == shape, name


def test_train_forward_shapes():
    p = params()
    tokens = jnp.zeros((2, 16), jnp.int32)
    logits = model_mod.forward_train(p, CFG, tokens)
    assert logits.shape == (2, 16, CFG.vocab_size)


def test_chunk_matches_train_forward():
    """Feeding a sequence through the cached chunk path must reproduce the
    full-sequence training forward (same math, different plumbing)."""
    p = params()
    tokens = np.array([[5, 9, 17, 3, 44, 8, 21, 60]], np.int32)
    full = model_mod.forward_train(p, CFG, jnp.asarray(tokens))
    full_logp = jax.nn.log_softmax(full, axis=-1)

    for use_pallas in (False, True):
        k_cache, v_cache = model_mod.init_cache(CFG, 1)
        kv_len = jnp.zeros((1,), jnp.int32)
        mask = jnp.ones((1, CFG.vocab_size))
        got_rows = []
        # Mixed chunk sizes to exercise the offset logic.
        for chunk in ([tokens[:, :3], tokens[:, 3:4], tokens[:, 4:8]]):
            logp, k_cache, v_cache = model_mod.forward_chunk(
                p, CFG, k_cache, v_cache, kv_len, jnp.asarray(chunk), mask,
                use_pallas=use_pallas,
            )
            got_rows.append(np.asarray(logp[0]))
            kv_len = kv_len + chunk.shape[1]
        got = np.concatenate(got_rows, axis=0)
        np.testing.assert_allclose(
            got, np.asarray(full_logp[0]), rtol=2e-4, atol=2e-4,
            err_msg=f"use_pallas={use_pallas}",
        )


def test_pallas_and_ref_paths_agree():
    p = params()
    k_cache, v_cache = model_mod.init_cache(CFG, 2)
    kv_len = jnp.asarray([0, 0], jnp.int32)
    tokens = jnp.asarray([[1, 2, 3, 4], [9, 8, 7, 6]], jnp.int32)
    mask = jnp.ones((2, CFG.vocab_size))
    a = model_mod.forward_chunk(p, CFG, k_cache, v_cache, kv_len, tokens, mask, use_pallas=True)
    b = model_mod.forward_chunk(p, CFG, k_cache, v_cache, kv_len, tokens, mask, use_pallas=False)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=2e-5, atol=2e-5)


def test_mask_applies_to_last_position_only():
    p = params()
    k_cache, v_cache = model_mod.init_cache(CFG, 1)
    kv_len = jnp.zeros((1,), jnp.int32)
    tokens = jnp.asarray([[4, 5]], jnp.int32)
    mask = jnp.ones((1, CFG.vocab_size)).at[0, 10:].set(0.0)
    logp, _, _ = model_mod.forward_chunk(p, CFG, k_cache, v_cache, kv_len, tokens, mask)
    assert bool(jnp.all(jnp.isinf(logp[0, -1, 10:])))
    assert bool(jnp.all(jnp.isfinite(logp[0, 0, :])))


def test_batch_lanes_independent():
    """A lane's output must not depend on other lanes' contents."""
    p = params()
    k_cache, v_cache = model_mod.init_cache(CFG, 2)
    kv_len = jnp.asarray([0, 0], jnp.int32)
    mask = jnp.ones((2, CFG.vocab_size))
    t_a = jnp.asarray([[1, 2, 3], [9, 9, 9]], jnp.int32)
    t_b = jnp.asarray([[1, 2, 3], [4, 4, 4]], jnp.int32)
    la, _, _ = model_mod.forward_chunk(p, CFG, k_cache, v_cache, kv_len, t_a, mask)
    lb, _, _ = model_mod.forward_chunk(p, CFG, k_cache, v_cache, kv_len, t_b, mask)
    np.testing.assert_allclose(np.asarray(la[0]), np.asarray(lb[0]), rtol=1e-5, atol=1e-5)


def test_loss_decreases_on_tiny_problem():
    """A few AdamW steps on a repetitive sequence must reduce loss."""
    from compile import train as train_mod

    p = params()
    opt = train_mod.adamw_init(p)
    tokens = jnp.asarray(np.tile(np.arange(8, dtype=np.int32), (4, 5))[:, :33])
    x, y = tokens[:, :-1], tokens[:, 1:]
    mask = jnp.ones_like(y, jnp.float32)

    first = float(model_mod.loss_fn(p, CFG, x, y, mask))
    for _ in range(30):
        loss, grads = jax.value_and_grad(lambda q: model_mod.loss_fn(q, CFG, x, y, mask))(p)
        p, opt = train_mod.adamw_update(p, grads, opt, 1e-2)
    last = float(model_mod.loss_fn(p, CFG, x, y, mask))
    assert last < first * 0.5, (first, last)
