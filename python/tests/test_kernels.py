"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes per the session's testing contract.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import decode_attention
from compile.kernels.masked_logits import masked_log_softmax
from compile.kernels.ref import decode_attention_ref, masked_log_softmax_ref


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    c=st.sampled_from([1, 2, 8]),
    d=st.sampled_from([8, 16, 32]),
    blocks=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
def test_decode_attention_matches_ref(b, h, c, d, blocks, seed):
    block = 128
    s = block * blocks
    q = rand(seed, (b, h, c, d))
    k = rand(seed + 1, (b, h, s, d))
    v = rand(seed + 2, (b, h, s, d))
    rng = np.random.default_rng(seed)
    kv_len = jnp.asarray(rng.integers(0, s - c, size=b), jnp.int32)
    got = decode_attention(q, k, v, kv_len, block=block)
    want = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_decode_attention_zero_kvlen():
    # Query 0 attends only to itself (kv_len=0, key 0 is its own slot).
    b, h, c, d, s = 1, 2, 1, 16, 128
    q = rand(0, (b, h, c, d))
    k = rand(1, (b, h, s, d))
    v = rand(2, (b, h, s, d))
    kv_len = jnp.zeros((b,), jnp.int32)
    got = decode_attention(q, k, v, kv_len)
    want = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    # Attending to exactly one key → output equals that value row.
    np.testing.assert_allclose(np.asarray(got[0, :, 0]), np.asarray(v[0, :, 0]), rtol=1e-5, atol=1e-5)


def test_decode_attention_rejects_bad_block():
    q = rand(0, (1, 1, 1, 8))
    k = rand(1, (1, 1, 100, 8))
    v = rand(2, (1, 1, 100, 8))
    with pytest.raises(ValueError):
        decode_attention(q, k, v, jnp.zeros((1,), jnp.int32), block=128)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 4),
    v=st.sampled_from([128, 256, 512]),
    density=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**16),
)
def test_masked_log_softmax_matches_ref(b, v, density, seed):
    logits = rand(seed, (b, v))
    rng = np.random.default_rng(seed)
    mask = (rng.random((b, v)) < density).astype(np.float32)
    mask[:, 0] = 1.0  # keep at least one token alive per row
    mask = jnp.asarray(mask)
    got = masked_log_softmax(logits, mask)
    want = masked_log_softmax_ref(logits, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_masked_log_softmax_normalizes():
    logits = rand(3, (2, 256))
    mask = jnp.ones((2, 256))
    out = masked_log_softmax(logits, mask)
    sums = jnp.sum(jnp.exp(out), axis=-1)
    np.testing.assert_allclose(np.asarray(sums), np.ones(2), rtol=1e-5)
    # Masked entries are exactly -inf.
    mask = mask.at[:, 100:].set(0.0)
    out = masked_log_softmax(logits, mask)
    assert bool(jnp.all(jnp.isinf(out[:, 100:])))
    sums = jnp.sum(jnp.exp(out[:, :100]), axis=-1)
    np.testing.assert_allclose(np.asarray(sums), np.ones(2), rtol=1e-5)


def test_masked_log_softmax_preserves_argmax():
    # Masking must not change the argmax among allowed tokens, and the
    # log-prob ordering must match the raw logits ordering.
    logits = rand(7, (1, 128))
    mask = jnp.ones((1, 128))
    out = masked_log_softmax(logits, mask)
    assert int(jnp.argmax(out)) == int(jnp.argmax(logits))
