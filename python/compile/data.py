"""Synthetic corpus + byte-level BPE (build-time data substrate).

The paper evaluates on GSM8K / CoNLL-2003 with Mistral-7B; neither the
datasets' licenses nor a 7B model fit this testbed, so we *simulate* (see
DESIGN.md §Substitutions): a seeded generator produces structured tasks in
the paper's exact output schemas (App. D), a small transformer is trained
on them at build time, and the rust eval harness generates held-out
problems from the same templates with known answers.

The BPE here mirrors ``rust/src/tokenizer`` exactly (same id layout:
0=EOS, 1=BOS, 2=PAD, 3..258 bytes, then merges; same greedy
most-frequent-pair trainer) and emits the shared ``tokenizer.json``.
"""

import json
import random

EOS_ID, BOS_ID, PAD_ID, NUM_SPECIAL = 0, 1, 2, 3

NAMES = ["Tom", "Anna", "Ben", "Mia", "Sam", "Lily", "Max", "Ruth", "Ivan", "Nora"]
ITEMS = ["apples", "books", "coins", "pens", "cards", "shells", "stamps", "rocks"]
JOBS = ["engineer", "doctor", "teacher", "artist", "pilot", "farmer", "writer", "nurse"]
CITIES = ["Paris", "Zurich", "Boston", "Tokyo", "Oslo", "Madrid", "Cairo", "Lima"]
ORGS = ["Acme Corp", "Globex", "Initech", "Umbrella", "Stark Labs", "Wayne Co"]
SURNAMES = ["Smith", "Doe", "Chen", "Garcia", "Patel", "Novak", "Kim", "Rossi"]


# --------------------------------------------------------------------------
# Task generators (formats shared with rust/src/eval/workload.rs)
# --------------------------------------------------------------------------

def gsm8k_task(rng: random.Random):
    """One synthetic math word problem + its schema answer (App. D)."""
    name = rng.choice(NAMES)
    item = rng.choice(ITEMS)
    kind = rng.randrange(3)
    if kind == 0:
        a, b = rng.randint(2, 12), rng.randint(2, 12)
        q = f"{name} has {a} {item} and buys {b} more. How many {item} does {name} have now?"
        step, calc, ans = f"Add the {item}", f"{a} + {b}", a + b
    elif kind == 1:
        a = rng.randint(4, 15)
        b = rng.randint(1, a - 1)
        q = f"{name} has {a} {item} and gives away {b}. How many {item} are left?"
        step, calc, ans = f"Subtract the given {item}", f"{a} - {b}", a - b
    else:
        a, b = rng.randint(2, 6), rng.randint(2, 6)
        q = f"{name} has {a} bags with {b} {item} each. How many {item} in total?"
        step, calc, ans = "Multiply bags by items", f"{a} * {b}", a * b
    answer = (
        '{"thoughts": [{"step": "%s", "calculation": "%s", "result": %d}], "answer": %d}'
        % (step, calc, ans, ans)
    )
    return q, answer, ans


def conll_task(rng: random.Random):
    """One synthetic NER sentence + its schema answer (App. D)."""
    person = f"{rng.choice(NAMES)} {rng.choice(SURNAMES)}"
    city = rng.choice(CITIES)
    org = rng.choice(ORGS)
    form = rng.randrange(3)
    if form == 0:
        sent = f"{person} works at {org} in {city}."
        ents = [(person, "PER"), (org, "ORG"), (city, "LOC")]
    elif form == 1:
        sent = f"{person} visited {city} last week."
        ents = [(person, "PER"), (city, "LOC")]
    else:
        sent = f"{org} opened an office in {city}."
        ents = [(org, "ORG"), (city, "LOC")]
    answer = (
        '{"entities": ['
        + ", ".join('{"entity": "%s", "type": "%s"}' % e for e in ents)
        + "]}"
    )
    return sent, answer, ents


def person_json(rng: random.Random) -> str:
    name = f"{rng.choice(NAMES)} {rng.choice(SURNAMES)}"
    age = rng.randint(18, 70)
    job = rng.choice(JOBS)
    if rng.random() < 0.5:
        return '{"name": "%s", "age": %d, "occupation": "%s"}' % (name, age, job)
    return '{\n  "name": "%s",\n  "age": %d,\n  "occupation": "%s"\n}' % (name, age, job)


def person_xml(rng: random.Random) -> str:
    name = f"{rng.choice(NAMES)} {rng.choice(SURNAMES)}"
    age = rng.randint(18, 70)
    job = rng.choice(JOBS)
    salary = rng.randint(30, 200) * 1000
    return (
        "<person>\n  <name>%s</name>\n  <age>%d</age>\n  <job>\n    <title>%s</title>\n"
        "    <salary>%d</salary>\n  </job>\n</person>" % (name, age, job, salary)
    )


def rpg_json(rng: random.Random) -> str:
    return (
        '{\n  "id": %d,\n  "description": "A nimble fighter",\n  "name": "%s",\n'
        '  "age": %d,\n  "armor": "%s",\n  "weapon": "%s",\n  "class": "%s",\n'
        '  "mantra": "%s",\n  "strength": %d,\n  "items": ["%s", "%s"]\n}'
        % (
            rng.randint(1, 99),
            rng.choice(NAMES),
            rng.randint(18, 60),
            rng.choice(["leather", "chainmail", "plate"]),
            rng.choice(["sword", "axe", "bow"]),
            rng.choice(["fighter", "ranger", "rogue"]),
            rng.choice(["strike true", "stay swift", "hold fast"]),
            rng.randint(3, 18),
            rng.choice(ITEMS),
            rng.choice(ITEMS),
        )
    )


def c_snippet(rng: random.Random) -> str:
    a, b = rng.randint(1, 9), rng.randint(1, 9)
    name = rng.choice(["main", "run", "calc"])
    variants = [
        'int %s() {\n  int a = %d;\n  int b = %d;\n  return a + b;\n}' % (name, a, b),
        'int %s() {\n  int x = %d;\n  x = x * %d;\n  return x;\n}' % (name, a, b),
        'int %s() {\n  int i = 0;\n  while (i < %d) {\n    i = i + 1;\n  }\n  return i;\n}'
        % (name, a + b),
    ]
    return rng.choice(variants)


# Prompt wrappers — the serving-side convention (rust mirrors these).
GSM8K_PROMPT = "Q: {q}\nA: "
CONLL_PROMPT = "Sentence: {s}\nEntities: "
PERSON_PROMPT = "A person encoded as JSON object:\n"
XML_PROMPT = "An XML file describing a person:\n"
RPG_PROMPT = "A character profile for an RPG game in JSON format:\n"
C_PROMPT = "A simple C function:\n"


def make_corpus(seed: int = 0, docs_per_kind: int = 600) -> list[str]:
    """The training documents (prompt + answer, one doc per task)."""
    rng = random.Random(seed)
    docs = []
    for _ in range(docs_per_kind):
        q, answer, _ = gsm8k_task(rng)
        docs.append(GSM8K_PROMPT.format(q=q) + answer)
        s, answer, _ = conll_task(rng)
        docs.append(CONLL_PROMPT.format(s=s) + answer)
        docs.append(PERSON_PROMPT + person_json(rng))
    for _ in range(docs_per_kind // 3):
        docs.append(XML_PROMPT + person_xml(rng))
        docs.append(RPG_PROMPT + rpg_json(rng))
        docs.append(C_PROMPT + c_snippet(rng))
    rng.shuffle(docs)
    return docs


# --------------------------------------------------------------------------
# Byte-level BPE (mirror of rust/src/tokenizer)
# --------------------------------------------------------------------------

class Tokenizer:
    def __init__(self, merges: list[tuple[int, int]]):
        self.tokens: list[bytes] = [b""] * NUM_SPECIAL + [bytes([i]) for i in range(256)]
        self.merges: list[tuple[int, int]] = []
        self.merge_map: dict[tuple[int, int], int] = {}
        for a, b in merges:
            self._push_merge(a, b)

    def _push_merge(self, a: int, b: int) -> int:
        new_id = len(self.tokens)
        self.tokens.append(self.tokens[a] + self.tokens[b])
        self.merge_map[(a, b)] = new_id
        self.merges.append((a, b))
        return new_id

    @property
    def vocab_size(self) -> int:
        return len(self.tokens)

    def encode(self, data: bytes) -> list[int]:
        ids = [b + NUM_SPECIAL for b in data]
        while len(ids) >= 2:
            best, best_i = None, -1
            for i in range(len(ids) - 1):
                m = self.merge_map.get((ids[i], ids[i + 1]))
                if m is not None and (best is None or m < best):
                    best, best_i = m, i
            if best is None:
                break
            pair = self.merges[best - NUM_SPECIAL - 256]
            out, i = [], 0
            while i < len(ids):
                if i + 1 < len(ids) and (ids[i], ids[i + 1]) == pair:
                    out.append(best)
                    i += 2
                else:
                    out.append(ids[i])
                    i += 1
            ids = out
        return ids

    def decode(self, ids: list[int]) -> bytes:
        return b"".join(self.tokens[i] for i in ids)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"merges": [list(m) for m in self.merges]}, f)

    @staticmethod
    def load(path: str) -> "Tokenizer":
        with open(path) as f:
            data = json.load(f)
        return Tokenizer([tuple(m) for m in data["merges"]])


def train_bpe(corpus: bytes, vocab_size: int, max_token_len: int = 10) -> Tokenizer:
    """Greedy most-frequent-pair BPE (ties → smallest pair, as in rust).

    ``max_token_len`` caps merged-token byte length: the synthetic corpus
    is repetitive enough that unbounded BPE merges 30-byte tokens spanning
    the prompt/answer boundary, which both defeats the alignment problem
    under study and starves the model of boundary contexts.
    """
    tok = Tokenizer([])
    ids = [b + NUM_SPECIAL for b in corpus]
    while tok.vocab_size < vocab_size:
        counts: dict[tuple[int, int], int] = {}
        for i in range(len(ids) - 1):
            p = (ids[i], ids[i + 1])
            if len(tok.tokens[p[0]]) + len(tok.tokens[p[1]]) > max_token_len:
                continue
            counts[p] = counts.get(p, 0) + 1
        if not counts:
            break
        pair, cnt = max(counts.items(), key=lambda kv: (kv[1], (-kv[0][0], -kv[0][1])))
        if cnt < 2:
            break
        new_id = tok._push_merge(*pair)
        out, i = [], 0
        while i < len(ids):
            if i + 1 < len(ids) and (ids[i], ids[i + 1]) == pair:
                out.append(new_id)
                i += 2
            else:
                out.append(ids[i])
                i += 1
        ids = out
    return tok
