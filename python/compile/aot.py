"""AOT bundle builder: corpus → tokenizer → trained weights → HLO text.

Usage: ``cd python && python -m compile.aot --out ../artifacts``

Emits into the output directory:
  tokenizer.json        — BPE merges (shared format with rust/src/tokenizer)
  weights.npz           — trained parameters, names per `model.param_manifest`
  model_config.json     — architecture + exported variants + input order
  model_b{B}_c{C}.hlo.txt — one HLO-text executable per (batch, chunk) shape
  train_log.json        — loss curve of the build-time training run

HLO *text* (not serialized proto) is the interchange format: jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import train as train_mod

# (batch, chunk) executable variants: decode step, speculation verify,
# prefill — each at B=1 (latency path) and B=4 (batched serving).
VARIANTS = [(1, 1), (1, 8), (1, 16), (4, 1), (4, 8), (4, 16)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_variant(cfg, params, batch, chunk, use_pallas=True):
    fn = model_mod.make_chunk_fn(cfg, use_pallas=use_pallas)
    leaves = model_mod.params_to_list(cfg, params)
    k_cache, v_cache = model_mod.init_cache(cfg, batch)
    example = (
        *[jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves],
        jax.ShapeDtypeStruct(k_cache.shape, k_cache.dtype),
        jax.ShapeDtypeStruct(v_cache.shape, v_cache.dtype),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch, chunk), jnp.int32),
        jax.ShapeDtypeStruct((batch, cfg.vocab_size), jnp.float32),
    )
    lowered = jax.jit(fn).lower(*example)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=int(os.environ.get("DOMINO_TRAIN_STEPS", 400)))
    ap.add_argument("--vocab-size", type=int, default=512)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--docs-per-kind", type=int, default=600)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    t0 = time.time()
    print("== corpus ==")
    docs = data_mod.make_corpus(seed=0, docs_per_kind=args.docs_per_kind)
    corpus_bytes = "\n".join(docs).encode()
    print(f"{len(docs)} docs, {len(corpus_bytes)} bytes")

    print("== tokenizer ==")
    # BPE training is quadratic-ish in python; a 100 KiB sample is plenty
    # for 253 merges.
    tok = data_mod.train_bpe(corpus_bytes[:100_000], args.vocab_size)
    tok.save(os.path.join(args.out, "tokenizer.json"))
    print(f"vocab {tok.vocab_size} ({time.time() - t0:.0f}s)")

    print("== train ==")
    cfg = model_mod.Config(vocab_size=tok.vocab_size)
    params, history = train_mod.train(
        cfg, tok, docs, steps=args.steps, seq_len=args.seq_len
    )
    train_mod.save_log(history, os.path.join(args.out, "train_log.json"))
    train_mod.save_weights(cfg, params, os.path.join(args.out, "weights.npz"))

    print("== export ==")
    variants = []
    for batch, chunk in VARIANTS:
        name = f"model_b{batch}_c{chunk}.hlo.txt"
        text = export_variant(cfg, params, batch, chunk)
        with open(os.path.join(args.out, name), "w") as f:
            f.write(text)
        variants.append({"batch": batch, "chunk": chunk, "file": name})
        print(f"{name}: {len(text)} chars")

    config = {
        "model": cfg.to_json(),
        "variants": variants,
        "param_order": [name for name, _ in model_mod.param_manifest(cfg)],
        "input_order": ["<params...>", "k_cache", "v_cache", "kv_len", "tokens", "mask"],
        "output_order": ["logprobs", "k_cache", "v_cache"],
    }
    with open(os.path.join(args.out, "model_config.json"), "w") as f:
        json.dump(config, f, indent=1)
    print(f"done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
