"""L1 Pallas kernel: fused constraint-mask + log-softmax over the vocab.

The final op of every served forward pass (Algorithm 1 line 7 fused with
normalization): the logit row never round-trips to HBM between masking and
the log-softmax reduction. Vocab is padded to a 128-lane multiple by the
model config, so one [1, V] VMEM block per batch lane is both VPU-friendly
and small (V ≤ 2048 → ≤ 8 KiB f32).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(logits_ref, mask_ref, o_ref):
    logits = logits_ref[...]
    mask = mask_ref[...] > 0
    masked = jnp.where(mask, logits, -jnp.inf)
    m = jnp.max(masked, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    ex = jnp.where(mask, jnp.exp(masked - m), 0.0)
    lse = jnp.log(jnp.sum(ex, axis=-1, keepdims=True)) + m
    o_ref[...] = jnp.where(mask, logits - lse, -jnp.inf).astype(o_ref.dtype)


@jax.jit
def masked_log_softmax(logits, mask):
    """Same contract as :func:`compile.kernels.ref.masked_log_softmax_ref`.

    logits: [B, V], mask: [B, V] {0., 1.} → [B, V] log-probs.
    """
    b, v = logits.shape
    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, v), lambda i: (i, 0)),
            pl.BlockSpec((1, v), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, v), logits.dtype),
        interpret=True,
    )(logits, mask)
