"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

These are also the functions the *training* path uses (training runs the
plain-jnp model; the AOT inference path swaps in the Pallas kernels, and
``python/tests/test_kernels.py`` asserts the two agree to float tolerance).
"""

import jax.numpy as jnp


def decode_attention_ref(q, k, v, kv_len):
    """Multi-query decode attention over a fixed-size KV cache.

    Args:
      q: [B, H, C, D]   — C query positions (C=1 for single-token decode).
      k: [B, H, S, D]   — key cache (padded to S).
      v: [B, H, S, D]   — value cache.
      kv_len: [B] int32 — per-lane number of valid cache entries *before*
        these C queries; query j may attend to keys < kv_len + j + 1.

    Returns:
      [B, H, C, D] attention output.
    """
    b, h, c, d = q.shape
    s = k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.array(d, q.dtype))
    scores = jnp.einsum("bhcd,bhsd->bhcs", q, k) * scale
    # Causal-with-offset mask: key position t visible to query j iff
    # t < kv_len + j + 1.
    tpos = jnp.arange(s)[None, None, None, :]
    limit = kv_len[:, None, None, None] + jnp.arange(c)[None, None, :, None] + 1
    scores = jnp.where(tpos < limit, scores, -jnp.inf)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhcs,bhsd->bhcd", probs, v)


def masked_log_softmax_ref(logits, mask):
    """Fused constraint-mask + log-softmax (Algorithm 1 line 7).

    Args:
      logits: [B, V] raw logits.
      mask: [B, V] {0., 1.} — 1 = token allowed.

    Returns:
      [B, V] log-probabilities; masked-out entries are -inf.
    """
    masked = jnp.where(mask > 0, logits, -jnp.inf)
    m = jnp.max(masked, axis=-1, keepdims=True)
    # Guard the all-masked row: max is -inf there; shift by 0 instead.
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    ex = jnp.where(mask > 0, jnp.exp(masked - m), 0.0)
    lse = jnp.log(jnp.sum(ex, axis=-1, keepdims=True)) + m
    return jnp.where(mask > 0, logits - lse, -jnp.inf)
