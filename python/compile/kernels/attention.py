"""L1 Pallas kernel: blocked decode attention with online softmax.

TPU adaptation of the GPU flash-decode pattern (DESIGN.md
§Hardware-Adaptation): the KV cache is tiled into VMEM-sized blocks via
``BlockSpec`` (the HBM↔VMEM schedule GPU kernels express with
threadblocks), the q·Kᵀ product is MXU-shaped ([C, D] × [D, BS]), and the
running max / normalizer / accumulator live in VMEM scratch across the
KV-block grid dimension (the online-softmax carry).

Always lowered with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls; numerics are identical (pytest asserts vs ``ref.py``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# KV block size (lane-dim multiple of 128 is the MXU-friendly choice; the
# fixed-size caches we serve are 256–512 entries → 2–4 blocks).
DEFAULT_BLOCK = 128


def _attn_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, block: int, scale: float):
    """One (batch, head, kv-block) grid step.

    Block shapes: q [1,1,C,D] · k,v [1,1,BS,D] · o [1,1,C,D];
    scratch: m,l [C,1], acc [C,D].
    """
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]  # [C, D]
    k = k_ref[0, 0]  # [BS, D]
    v = v_ref[0, 0]  # [BS, D]
    c = q.shape[0]

    scores = jnp.dot(q, k.T) * scale  # [C, BS] — the MXU product
    # Visibility: key global position t < kv_len + (query index) + 1.
    tpos = si * block + jax.lax.broadcasted_iota(jnp.int32, (c, block), 1)
    limit = kvlen_ref[0] + jax.lax.broadcasted_iota(jnp.int32, (c, block), 0) + 1
    scores = jnp.where(tpos < limit, scores, -jnp.inf)

    # Online softmax update (carries in VMEM scratch).
    m_prev = m_ref[...]  # [C, 1]
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # exp with -inf rows guarded (fully-masked block → contributes zero).
    p = jnp.where(jnp.isfinite(scores), jnp.exp(scores - m_new), 0.0)  # [C, BS]
    correction = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
    l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * correction + jnp.dot(p, v)
    m_ref[...] = m_new

    @pl.when(si == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def decode_attention(q, k, v, kv_len, *, block: int = DEFAULT_BLOCK):
    """Pallas decode attention. Same contract as
    :func:`compile.kernels.ref.decode_attention_ref`.

    q: [B,H,C,D], k/v: [B,H,S,D], kv_len: [B] int32 → [B,H,C,D].
    """
    b, h, c, d = q.shape
    s = k.shape[2]
    if s % block != 0:
        raise ValueError(f"cache length {s} must be a multiple of block {block}")
    scale = 1.0 / (d**0.5)
    grid = (b, h, s // block)
    kernel = functools.partial(_attn_kernel, block=block, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, si: (bi,)),
            pl.BlockSpec((1, 1, c, d), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block, d), lambda bi, hi, si: (bi, hi, si, 0)),
            pl.BlockSpec((1, 1, block, d), lambda bi, hi, si: (bi, hi, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, c, d), lambda bi, hi, si: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, c, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((c, 1), jnp.float32),
            pltpu.VMEM((c, 1), jnp.float32),
            pltpu.VMEM((c, d), jnp.float32),
        ],
        interpret=True,
    )(kv_len, q, k, v)
