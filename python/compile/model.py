"""L2: the JAX serving model — a small decoder-only transformer.

Two forward paths over the SAME parameters:

* :func:`forward_train` — full-sequence causal forward (plain jnp; used by
  ``train.py`` where Pallas-interpret would be needlessly slow).
* :func:`forward_chunk` — the *served* path: C tokens appended to a
  fixed-size functional KV cache, per-lane positions, calling the L1
  Pallas kernels (``kernels.attention``, ``kernels.masked_logits``), and
  returning log-probs with the constraint mask fused into the final
  normalization. ``use_pallas=False`` swaps in the ``ref.py`` oracles —
  pytest asserts both paths agree.

The KV cache is functional (inputs → outputs), so speculative rollback is
free: the coordinator just reuses the pre-call buffers (§3.6).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import masked_logits as ml_kernel
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class Config:
    vocab_size: int = 512          # multiple of 128 (VPU lanes)
    d_model: int = 128
    n_layers: int = 3
    n_heads: int = 4
    d_ff: int = 288
    max_seq: int = 384             # KV cache size; multiple of the KV block
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "Config":
        return Config(**d)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_manifest(cfg: Config):
    """Ordered (name, shape) list — the executable input order contract
    shared with the rust runtime (weights.npz uses these names)."""
    out = [("emb", (cfg.vocab_size, cfg.d_model))]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        out += [
            (p + "norm1", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "norm2", (cfg.d_model,)),
            (p + "w_gate", (cfg.d_model, cfg.d_ff)),
            (p + "w_up", (cfg.d_model, cfg.d_ff)),
            (p + "w_down", (cfg.d_ff, cfg.d_model)),
        ]
    out.append(("norm_f", (cfg.d_model,)))
    return out


def init_params(cfg: Config, key) -> dict:
    params = {}
    for name, shape in param_manifest(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("norm1", "norm2", "norm_f")):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = jax.random.normal(sub, shape, jnp.float32) * (fan_in**-0.5)
    return params


def params_to_list(cfg: Config, params: dict):
    return [params[name] for name, _ in param_manifest(cfg)]


def params_from_list(cfg: Config, leaves):
    return {name: leaf for (name, _), leaf in zip(param_manifest(cfg), leaves)}


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def rmsnorm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * g


def _rope(x, positions, theta):
    """Rotary embedding. x: [..., T, H, Dh], positions: [..., T]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _swiglu(x, wg, wu, wd):
    return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd


# --------------------------------------------------------------------------
# Training path (full sequence, no cache)
# --------------------------------------------------------------------------

def forward_train(params: dict, cfg: Config, tokens):
    """tokens [B, T] → logits [B, T, V] (plain jnp, causal)."""
    b, t = tokens.shape
    h, dh = cfg.n_heads, cfg.head_dim
    x = params["emb"][tokens]  # [B, T, D]
    positions = jnp.arange(t)[None, :].repeat(b, axis=0)
    causal = jnp.tril(jnp.ones((t, t), bool))
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        y = rmsnorm(x, params[p + "norm1"])
        q = _rope((y @ params[p + "wq"]).reshape(b, t, h, dh), positions, cfg.rope_theta)
        k = _rope((y @ params[p + "wk"]).reshape(b, t, h, dh), positions, cfg.rope_theta)
        v = (y @ params[p + "wv"]).reshape(b, t, h, dh)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (dh**0.5)
        scores = jnp.where(causal[None, None], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, t, cfg.d_model)
        x = x + o @ params[p + "wo"]
        y = rmsnorm(x, params[p + "norm2"])
        x = x + _swiglu(y, params[p + "w_gate"], params[p + "w_up"], params[p + "w_down"])
    x = rmsnorm(x, params["norm_f"])
    return x @ params["emb"].T  # tied head


# --------------------------------------------------------------------------
# Serving path (chunked, functional KV cache, L1 kernels)
# --------------------------------------------------------------------------

def init_cache(cfg: Config, batch: int):
    shape = (cfg.n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def forward_chunk(params: dict, cfg: Config, k_cache, v_cache, kv_len, tokens, mask,
                  use_pallas: bool = True):
    """Append C tokens per lane; return per-position log-probs.

    Args:
      k_cache, v_cache: [L, B, H, S, Dh] functional caches.
      kv_len: [B] int32 — tokens already in each lane.
      tokens: [B, C] int32.
      mask: [B, V] {0,1} — constraint mask for the *last* position
        (earlier positions get all-ones: they are scored, not constrained).

    Returns:
      (logprobs [B, C, V], k_cache', v_cache').
    """
    b, c = tokens.shape
    h, dh = cfg.n_heads, cfg.head_dim
    x = params["emb"][tokens]  # [B, C, D]
    positions = kv_len[:, None] + jnp.arange(c)[None, :]  # [B, C]

    attn = attn_kernel.decode_attention if use_pallas else kref.decode_attention_ref
    mls = ml_kernel.masked_log_softmax if use_pallas else kref.masked_log_softmax_ref

    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        y = rmsnorm(x, params[p + "norm1"])
        q = _rope((y @ params[p + "wq"]).reshape(b, c, h, dh), positions, cfg.rope_theta)
        k = _rope((y @ params[p + "wk"]).reshape(b, c, h, dh), positions, cfg.rope_theta)
        v = (y @ params[p + "wv"]).reshape(b, c, h, dh)
        # Scatter the C new entries at each lane's offset (per-lane starts →
        # vmapped dynamic_update_slice).
        upd = jax.vmap(lambda cache, new, p0: jax.lax.dynamic_update_slice(cache, new, (0, p0, 0)))
        kc = upd(k_cache[i], k.transpose(0, 2, 1, 3), kv_len)  # [B, H, S, Dh]
        vc = upd(v_cache[i], v.transpose(0, 2, 1, 3), kv_len)
        new_k.append(kc)
        new_v.append(vc)
        o = attn(q.transpose(0, 2, 1, 3), kc, vc, kv_len)  # [B, H, C, Dh]
        o = o.transpose(0, 2, 1, 3).reshape(b, c, cfg.d_model)
        x = x + o @ params[p + "wo"]
        y = rmsnorm(x, params[p + "norm2"])
        x = x + _swiglu(y, params[p + "w_gate"], params[p + "w_up"], params[p + "w_down"])

    x = rmsnorm(x, params["norm_f"])
    logits = x @ params["emb"].T  # [B, C, V]
    # Fused mask+log-softmax: all-ones for positions < C-1, `mask` for the
    # last (the only position a new token is decoded from).
    v_sz = cfg.vocab_size
    full_mask = jnp.concatenate(
        [jnp.ones((b, c - 1, v_sz), logits.dtype), mask[:, None, :]], axis=1
    ) if c > 1 else mask[:, None, :]
    logprobs = mls(logits.reshape(b * c, v_sz), full_mask.reshape(b * c, v_sz))
    return (
        logprobs.reshape(b, c, v_sz),
        jnp.stack(new_k),
        jnp.stack(new_v),
    )


def make_chunk_fn(cfg: Config, use_pallas: bool = True):
    """The function lowered to HLO for one (B, C) shape: takes the flat
    parameter list followed by the runtime inputs (the rust side's calling
    convention)."""
    n_params = len(param_manifest(cfg))

    def fn(*args):
        leaves = args[:n_params]
        k_cache, v_cache, kv_len, tokens, mask = args[n_params:]
        params = params_from_list(cfg, leaves)
        return forward_chunk(params, cfg, k_cache, v_cache, kv_len, tokens, mask,
                             use_pallas=use_pallas)

    return fn


@functools.partial(jax.jit, static_argnames=("cfg",))
def loss_fn(params: dict, cfg: Config, tokens, targets, loss_mask):
    """Mean next-token cross-entropy (targets = tokens shifted by 1)."""
    logits = forward_train(params, cfg, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * loss_mask) / jnp.maximum(jnp.sum(loss_mask), 1.0)
