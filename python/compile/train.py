"""Build-time training of the serving model (hand-rolled AdamW — the image
has no optax).

Trains the plain-jnp path (``forward_train``) on the synthetic corpus and
records the loss curve to ``train_log.json`` (EXPERIMENTS.md's end-to-end
evidence). The resulting weights are served through the Pallas-kernel path
— ``tests/test_model.py`` asserts the two paths agree.
"""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod


def build_stream(tok, docs, seq_len):
    """Concatenate EOS-separated docs and window into [N, seq_len+1]."""
    ids = []
    for doc in docs:
        ids.extend(tok.encode(doc.encode()))
        ids.append(data_mod.EOS_ID)
    n = (len(ids) - 1) // seq_len
    windows = np.zeros((n, seq_len + 1), np.int32)
    for i in range(n):
        windows[i] = ids[i * seq_len : i * seq_len + seq_len + 1]
    return windows


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, wd=0.01, b1=0.9, b2=0.95, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    corr1 = 1 - b1**tf
    corr2 = 1 - b2**tf
    new_params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ / corr1 / (jnp.sqrt(v_ / corr2) + eps) + wd * p),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train(cfg: model_mod.Config, tok, docs, *, steps=400, batch=16, seq_len=128,
          lr=3e-3, seed=0, log_every=20, log=print):
    windows = build_stream(tok, docs, seq_len)
    log(f"corpus: {len(docs)} docs -> {windows.shape[0]} windows of {seq_len}")
    key = jax.random.PRNGKey(seed)
    params = model_mod.init_params(cfg, key)
    opt = adamw_init(params)

    @jax.jit
    def step_fn(params, opt, batch_tokens, lr_now):
        tokens = batch_tokens[:, :-1]
        targets = batch_tokens[:, 1:]
        mask = jnp.ones_like(targets, jnp.float32)
        loss, grads = jax.value_and_grad(
            lambda p: model_mod.loss_fn(p, cfg, tokens, targets, mask)
        )(params)
        params, opt = adamw_update(params, grads, opt, lr_now)
        return params, opt, loss

    rng = np.random.default_rng(seed)
    history = []
    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, windows.shape[0], size=batch)
        lr_now = lr * 0.5 * (1 + np.cos(np.pi * step / steps))
        # Short warmup.
        if step < 20:
            lr_now = lr * (step + 1) / 20
        params, opt, loss = step_fn(params, opt, jnp.asarray(windows[idx]), jnp.float32(lr_now))
        if step % log_every == 0 or step == steps - 1:
            loss_v = float(loss)
            history.append({"step": step, "loss": loss_v, "elapsed_s": time.time() - t0})
            log(f"step {step:4d}  loss {loss_v:.4f}  ({time.time() - t0:.0f}s)")
    return params, history


def save_weights(cfg, params, path):
    manifest = model_mod.param_manifest(cfg)
    np.savez(path, **{name: np.asarray(params[name]) for name, _ in manifest})


def save_log(history, path):
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
